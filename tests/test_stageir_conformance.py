"""Property-based conformance suite for the stage-IR lowering contract.

docs/pipeline_ir.md promises four invariants that every backend must keep
as new backends/stages land; this suite pins them over *randomly configured
trained models* (vendored hypothesis shim — example 0 is always the minimal
configuration, so boundary topologies are exercised every run):

  1. compiled == eager: ``Pipeline.run`` (the jitted, peephole-fused stage
     program) equals the eager unfused stage walk bit-for-bit, on every
     backend;
  2. execution == training math: dense backends match
     ``TrainedModel.predict`` exactly; the MAT backend is
     quantization-bounded (<=3% label flips at 512 bins), trees exact;
  3. accounting == execution: the shape-only ``lower_topology`` specs that
     feasibility charges carry the same layer shapes / parameter counts /
     table arities as the executable stages actually run;
  4. pallas == interpreter: the Pallas serving backend
     (docs/pipeline_ir.md#pallas-lowering-contract) is bit-exact on dense
     pipelines, quantization-bounded on MAT pipelines, and honestly
     reports interpreter fallback for kernel-ineligible sequences;
  5. flow state (docs/pipeline_ir.md#flow-state-contract): the fused
     flow-update kernel produces bit-identical register state, feature
     rows and verdicts to the jnp scan reference over randomly configured
     register files and collision-heavy packet batches, and the stateful
     accounting specs equal the stage metadata.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import codegen, feasibility as feas, mlalgos, stageir
from repro.core import pallas_backend
from repro.core.stageir import (
    CentroidDistance,
    Dense,
    FusedMLP,
    LUTGather,
    Quantize,
    TreeTraverse,
    apply_stages,
    lower_topology,
    spec_layers,
    spec_params,
    stage_summary,
)
from repro.data import netdata

pytestmark = pytest.mark.slow

HSET = settings(max_examples=5, deadline=None)

# small fixed datasets: one binary, one 5-class (both 7 features); widths
# drawn from a small menu so jit-compile caches carry across examples
_AD = netdata.make_ad_dataset(features=7, n_train=384, n_test=192)
_TC = netdata.make_tc_dataset(n_train=384, n_test=192)

_HIDDEN = ((4,), (8,), (4, 8), (8, 8))


def _train(algo: str, draw, data):
    if algo in ("dnn", "logreg"):
        cfg = {"lr": 3e-3, "batch": 128, "epochs": 1}
        if algo == "dnn":
            hidden = draw(st.sampled_from(_HIDDEN))
            cfg["n_layers"] = len(hidden)
            for i, h in enumerate(hidden):
                cfg[f"h{i}"] = h
        return mlalgos.train(algo, data, cfg, seed=1)
    if algo == "kmeans":
        cfg = {"k": draw(st.integers(1, 6)),
               "n_features": draw(st.integers(2, data.num_features))}
        return mlalgos.train(algo, data, cfg, seed=1)
    if algo == "svm":
        return mlalgos.train("svm", data, {"c_reg": 1.0}, seed=1)
    if algo == "tree":
        return mlalgos.train(
            "tree", data, {"max_depth": draw(st.integers(2, 4))}, seed=1)
    raise KeyError(algo)


def _run_compiled(stages, X):
    return np.asarray(stageir.compile_stages(stages)(
        jnp.asarray(X, jnp.float32)))


def _run_eager(stages, X):
    return np.asarray(apply_stages(stages, jnp.asarray(X, jnp.float32)))


# ------------------------------------------------- dense (taurus/fpga/tpu)


@given(data=st.data(),
       algo=st.sampled_from(["dnn", "logreg", "svm", "kmeans"]),
       multiclass=st.booleans())
@HSET
def test_dense_backend_conformance(data, algo, multiclass):
    ds = _TC if multiclass else _AD
    trained = _train(algo, data.draw, ds)
    stages = codegen.taurus_stages(trained)
    X = ds.test_x

    # (1) jitted+fused whole-pipeline program == eager unfused stage walk
    np.testing.assert_array_equal(_run_compiled(stages, X),
                                  _run_eager(stages, X))
    # (2) execution math == training math, exactly (same argmax tie-break)
    pipe = codegen.taurus_codegen("c", trained, _report())
    np.testing.assert_array_equal(pipe(X), trained.predict(X))

    # (3) the specs feasibility charges == the stages execution runs
    specs = lower_topology(trained.algorithm, trained.topology, form="dense")
    assert spec_params(specs) == stage_summary(stages)["params"]
    assert feas.topology_params(trained.algorithm, trained.topology) \
        == trained.param_count
    exec_layers = []
    for s in stages:
        if isinstance(s, FusedMLP):
            m = s.meta()["widths"]
            exec_layers += list(zip(m, m[1:]))
        elif isinstance(s, Dense):
            exec_layers.append((s.meta()["n_in"], s.meta()["n_out"]))
        elif isinstance(s, CentroidDistance):
            exec_layers.append((s.meta()["n_in"], s.meta()["n_out"]))
    assert spec_layers(specs) == exec_layers


# ----------------------------------------------------------- MAT (tofino)


@given(data=st.data(), algo=st.sampled_from(["svm", "logreg", "kmeans",
                                             "tree"]))
@HSET
def test_mat_backend_conformance(data, algo):
    ds = _AD
    trained = _train(algo, data.draw, ds)
    stages = codegen.mat_stages(trained, ds.train_x)
    X = ds.test_x

    # (1) compiled == eager, bit-for-bit, on the MAT dataflow too
    np.testing.assert_array_equal(_run_compiled(stages, X),
                                  _run_eager(stages, X))
    # (2) tree is exact; quantized LUT forms are 3%-bounded (the contract)
    pipe = codegen.mat_codegen("c", trained, _report(), ds.train_x)
    if algo == "tree":
        np.testing.assert_array_equal(pipe(X), trained.predict(X))
    else:
        assert pipe.verify(X, max_mismatch_frac=0.03) <= 0.03

    # (3) MAT specs charge what the executable tables hold
    specs = lower_topology(algo, trained.topology, form="mat")
    mats = feas.MATModel().mats_for(algo, trained.topology)
    if algo == "tree":
        trav = next(s for s in stages if isinstance(s, TreeTraverse))
        assert mats == trav.depth
        assert specs[0].params == trav.meta()["n_nodes"]
    else:
        quant = next(s for s in stages if isinstance(s, Quantize))
        lut = next(s for s in stages if isinstance(s, LUTGather))
        qspec = next(s for s in specs if s.kind == "quantize")
        lspec = next(s for s in specs if s.kind == "lut_gather")
        assert qspec.extra[0] == quant.meta()["bins"] == stageir.MAT_BINS
        assert lspec.params == lut.meta()["params"] == lut.tables.size
        assert mats == (lut.meta()["n_out"] if algo == "kmeans"
                        else lut.meta()["n_features"])


def _report():
    return feas.FeasibilityReport(True, [], {"cu": 1, "mu": 1}, 1.0, 1e9)


# ------------------------------------------- Pallas serving backend parity
#
# Every property case above re-runs with backend="pallas"; the contract
# (docs/pipeline_ir.md#pallas-lowering-contract): bit-exact on dense
# pipelines, quantization-bounded on MAT pipelines, honest interpreter
# fallback for kernel-ineligible stage sequences.

needs_pallas = pytest.mark.skipif(
    not pallas_backend.pallas_available(),
    reason="Pallas toolchain unavailable in this environment",
)


@needs_pallas
@given(data=st.data(),
       algo=st.sampled_from(["dnn", "logreg", "svm", "kmeans"]),
       multiclass=st.booleans())
@HSET
def test_dense_backend_pallas_parity(data, algo, multiclass):
    ds = _TC if multiclass else _AD
    trained = _train(algo, data.draw, ds)
    stages = codegen.taurus_stages(trained)
    X = ds.test_x

    interp = stageir.compile_stages(stages, backend="interpret")
    pallas = stageir.compile_stages(stages, backend="pallas")
    # MLP-shaped pipelines lower onto the fused kernel; CentroidDistance
    # (kmeans) is outside the envelope and must report the fallback
    expected = "interpret" if algo == "kmeans" else "pallas"
    assert pallas.requested_backend == "pallas"
    assert pallas.backend == expected
    # dense contract: bit-exact, whatever engine actually serves
    np.testing.assert_array_equal(
        np.asarray(interp(jnp.asarray(X, jnp.float32))),
        np.asarray(pallas(jnp.asarray(X, jnp.float32))),
    )
    # the generated Pipeline serves through the same engine and still
    # verifies exactly against the training math
    pipe = codegen.taurus_codegen("c", trained, _report(),
                                  exec_backend="pallas")
    assert pipe.compiled_backend == expected
    np.testing.assert_array_equal(pipe(X), trained.predict(X))


@needs_pallas
@given(data=st.data(), algo=st.sampled_from(["svm", "logreg", "kmeans",
                                             "tree"]))
@HSET
def test_mat_backend_pallas_parity(data, algo):
    ds = _AD
    trained = _train(algo, data.draw, ds)
    stages = codegen.mat_stages(trained, ds.train_x)
    X = ds.test_x

    interp = stageir.compile_stages(stages, backend="interpret")
    pallas = stageir.compile_stages(stages, backend="pallas")
    a = np.asarray(interp(jnp.asarray(X, jnp.float32)))
    b = np.asarray(pallas(jnp.asarray(X, jnp.float32)))
    if algo == "tree":
        # TreeTraverse is kernel-ineligible: honest fallback, exact
        assert pallas.backend == "interpret"
        np.testing.assert_array_equal(a, b)
    else:
        # quantized-LUT pipelines fuse into one mat_lut kernel launch;
        # agreement with the interpreter is quantization-bounded (the
        # same <=3% contract the MAT backend itself carries — in practice
        # the one-hot-matmul gather reproduces the verdicts exactly)
        assert pallas.backend == "pallas"
        assert float(np.mean(a != b)) <= 0.03
        pipe = codegen.mat_codegen("c", trained, _report(), ds.train_x,
                                   exec_backend="pallas")
        assert pipe.compiled_backend == "pallas"
        assert pipe.verify(X, max_mismatch_frac=0.03) <= 0.03


# --------------------------------------------- fused-DAG megakernel parity
#
# Random Seq/Par DAGs over kernel-eligible dense models: the whole-DAG
# megakernel (chaining.compile_dag(..., backend="pallas") ->
# "pallas-fused-dag") must match the eager run_dag reference bit-for-bit
# and agree with the per-model-launch baseline (fuse_dag=False) — Seq
# gating, Par or/and merges and duplicate-model sharing included.


def _dag_leaf(name):
    from repro.core.alchemy import Model

    return Model({"name": name, "data_loader": lambda: None,
                  "algorithm": None})


@needs_pallas
@given(data=st.data())
@HSET
def test_fused_dag_megakernel_conformance(data):
    from repro.core import chaining

    ds = _AD
    names = ["m0", "m1", "m2"]
    pipes = {}
    for i, name in enumerate(names):
        algo = data.draw(st.sampled_from(["dnn", "svm", "logreg"]))
        trained = _train(algo, data.draw, ds)
        pipes[name] = codegen.taurus_codegen(name, trained, _report())
    combine = data.draw(st.sampled_from(["or", "and"]))
    shape = data.draw(st.sampled_from([
        "a>b", "a|b", "a>(b|c)", "(a|b)>c", "a>b>c", "a>a",
    ]))
    a, b, c = (_dag_leaf(n) for n in names)
    node = {
        "a>b": lambda: a > b,
        "a|b": lambda: a | b,
        "a>(b|c)": lambda: a > (b | c),
        "(a|b)>c": lambda: (a | b) > c,
        "a>b>c": lambda: a > b > c,
        "a>a": lambda: a > _dag_leaf("m0"),   # duplicate model shares weights
    }[shape]()

    X = ds.test_x
    ref = chaining.run_dag(node, pipes, X, combine=combine)
    fused = chaining.compile_dag(node, pipes, backend="pallas",
                                 combine=combine)
    assert fused.backend == "pallas-fused-dag", (
        f"{shape} with dense leaves must fuse, got {fused.backend}"
    )
    np.testing.assert_array_equal(ref, fused(X))
    per_model = chaining.compile_dag(node, pipes, backend="pallas",
                                     combine=combine, fuse_dag=False)
    np.testing.assert_array_equal(ref, per_model(X))
    interp = chaining.compile_dag(node, pipes, combine=combine)
    np.testing.assert_array_equal(ref, interp(X))


# ------------------------------------------- flow-state kernel conformance
#
# Random register-file configurations x collision-heavy packet batches:
# the Pallas scatter/gather kernel's hybrid round schedule must reproduce
# the sequential jnp reference BIT-FOR-BIT (state, features, verdicts),
# and the shape-only accounting specs must equal the stage metadata.


def _draw_flow_setup(draw):
    from repro.flowstate import FlowStateSpec

    n_slots = draw(st.sampled_from((4, 8, 32, 128)))
    n_counters = draw(st.integers(1, 3))
    n_ewma = draw(st.integers(0, 2))
    n_hists = draw(st.integers(0, 2))
    hist_sizes = tuple(draw(st.integers(2, 9)) for _ in range(n_hists))
    alpha = draw(st.sampled_from((0.0625, 0.125, 0.5)))
    spec = FlowStateSpec(n_slots=n_slots, n_counters=n_counters,
                         n_ewma=n_ewma, hist_sizes=hist_sizes,
                         ewma_alpha=alpha)
    rng = np.random.default_rng(draw(st.integers(0, 10_000)))
    B = draw(st.integers(1, 160))
    n_flows = draw(st.sampled_from((1, 2, 5, 40, 500)))
    pk = rng.integers(0, n_flows, B).astype(np.int32)
    upd = rng.normal(size=(B, n_counters + n_ewma)).astype(np.float32)
    offs = spec.hist_offsets
    if n_hists:
        bins = np.stack([
            offs[j] + rng.integers(0, hist_sizes[j], B)
            for j in range(n_hists)
        ], 1).astype(np.int32)
    else:
        bins = np.full((B, 1), -1, np.int32)
    valid = (rng.random(B) < 0.9).astype(np.int32)
    # start from a partially occupied, partially dirty table
    keys0 = np.full(spec.n_slots, -1, np.int32)
    occ = rng.random(spec.n_slots) < 0.5
    keys0[occ] = rng.integers(0, n_flows, occ.sum())
    regs0 = np.where(
        occ[:, None],
        np.abs(rng.normal(size=(spec.n_slots, spec.width))), 0.0
    ).astype(np.float32)
    return spec, keys0, regs0, pk, upd, bins, valid


needs_flow_pallas = pytest.mark.skipif(
    not pallas_backend.pallas_available(),
    reason="Pallas toolchain unavailable in this environment",
)


@needs_flow_pallas
@given(data=st.data())
@HSET
def test_flow_update_kernel_bit_identical(data):
    from repro.kernels.flow_update import flow_update, flow_update_ref

    spec, keys0, regs0, pk, upd, bins, valid = _draw_flow_setup(data.draw)
    kw = dict(n_counters=spec.n_counters, n_ewma=spec.n_ewma,
              alpha=spec.ewma_alpha)
    ref = flow_update_ref(keys0, regs0, pk, upd, bins, valid, **kw)
    ker = flow_update(keys0, regs0, pk, upd, bins, valid, **kw)
    for a, b, name in zip(ref, ker, ("keys", "regs", "feats")):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"flow-update kernel diverged on {name} "
                    f"(slots={spec.n_slots}, width={spec.width})",
        )


@needs_flow_pallas
@given(data=st.data())
@HSET
def test_stateful_pipeline_backend_parity(data):
    """Whole stateful pipelines (registers + random MLP classifier) serve
    bit-identical state AND verdicts on both engines; backend reporting
    stays honest."""
    from repro.flowstate import FlowStateSpec, StatefulPipeline

    rng = np.random.default_rng(data.draw(st.integers(0, 10_000)))
    n_slots = data.draw(st.sampled_from((8, 64)))
    hist = data.draw(st.integers(3, 8))
    spec = FlowStateSpec(n_slots=n_slots, n_counters=1, n_ewma=1,
                         hist_sizes=(hist,), ewma_alpha=0.125)
    fk = stageir.FlowKey((0,), n_slots)
    ru = stageir.RegisterUpdate(
        spec, ewma_cols=(1,), hist_cols=(1,),
        hist_edges=(np.linspace(0.0, 1.0, hist + 1)[1:-1],),
    )
    ws = stageir.WindowStats(spec, mode=data.draw(st.sampled_from(
        ("all", "hist"))))
    hidden = data.draw(st.sampled_from((4, 8)))
    w1 = rng.normal(size=(ws.n_out, hidden)).astype(np.float32)
    w2 = rng.normal(size=(hidden, 3)).astype(np.float32)
    mlp = stageir.FusedMLP(
        [w1, w2], [np.zeros(hidden, np.float32), np.zeros(3, np.float32)]
    )
    stages = [fk, ru, ws, mlp, stageir.Reduce("argmax")]

    B = data.draw(st.integers(1, 120))
    X = np.zeros((B, 2), np.float32)
    X[:, 0] = rng.integers(0, data.draw(st.sampled_from((2, 30))), B)
    X[:, 1] = rng.random(B)

    pi = StatefulPipeline(stages, backend="interpret")
    pp = StatefulPipeline(stages, backend="pallas")
    assert pi.backend == "interpret"
    assert pp.backend == "pallas-fused-flow"
    assert pp.requested_backend == "pallas"
    si, vi = pi(pi.init_state(), X)
    sp, vp = pp(pp.init_state(), X)
    np.testing.assert_array_equal(np.asarray(si.keys), np.asarray(sp.keys))
    np.testing.assert_array_equal(np.asarray(si.regs), np.asarray(sp.regs))
    np.testing.assert_array_equal(vi, vp)


@given(data=st.data())
@HSET
def test_flowstate_specs_equal_stage_meta(data):
    """Invariant (3) for the stateful vocabulary: what feasibility charges
    (flowstate_specs) is what the executable stages carry (meta)."""
    spec, *_ = _draw_flow_setup(data.draw)
    specs = stageir.flowstate_specs(spec)
    by_kind = {s.kind: s for s in specs}
    edges = tuple(
        np.linspace(0.0, 1.0, h + 1)[1:-1] for h in spec.hist_sizes
    )
    ru = stageir.RegisterUpdate(
        spec,
        counter_cols=tuple(1 for _ in range(spec.n_counters - 1)),
        ewma_cols=tuple(1 for _ in range(spec.n_ewma)),
        hist_cols=tuple(1 for _ in range(len(spec.hist_sizes))),
        hist_edges=edges,
    )
    assert by_kind["register_update"].params == ru.meta()["params"] \
        == spec.n_slots * (spec.width + 1)
    assert by_kind["register_update"].extra == (spec.n_slots, spec.width)
    ws = stageir.WindowStats(spec, mode="all")
    assert by_kind["window_stats"].n_out == ws.n_out == ws.meta()["n_out"]
    rep = feas.flowstate_report(spec, "taurus")
    assert rep.resources["register_words"] \
        == by_kind["register_update"].params
