"""Fault tolerance: checkpoint integrity, bitwise-identical restart,
straggler watchdog, elastic N->M reshard (subprocess with 8 devices)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (
    AsyncCheckpointer, latest_step, restore_checkpoint, save_checkpoint,
)
from repro.configs import get_smoke_config
from repro.data.tokens import TokenDataset
from repro.ft.restart import RestartManager, StragglerWatchdog
from repro.train.step import TrainSettings, init_train_state, make_train_step


def _tiny_state():
    return {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.bfloat16)},
        "step": jnp.array(7, jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path):
    state = _tiny_state()
    save_checkpoint(str(tmp_path), state, 7)
    restored, step = restore_checkpoint(str(tmp_path), state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_crc_detects_corruption(tmp_path):
    state = _tiny_state()
    d = save_checkpoint(str(tmp_path), state, 1)
    victim = os.path.join(d, "leaf_00000.bin.zst")
    with open(victim, "rb") as f:
        raw = bytearray(f.read())
    raw[len(raw) // 2] ^= 0xFF
    with open(victim, "wb") as f:
        f.write(raw)
    with pytest.raises(Exception):
        restore_checkpoint(str(tmp_path), state)


def test_latest_step_ignores_incomplete(tmp_path):
    state = _tiny_state()
    save_checkpoint(str(tmp_path), state, 5)
    # a crashed save: directory without COMPLETE
    os.makedirs(tmp_path / "step_0000000009")
    with open(tmp_path / "latest", "w") as f:
        f.write("9")
    assert latest_step(str(tmp_path)) == 5


def test_async_checkpointer_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    state = _tiny_state()
    for s in (1, 2, 3, 4):
        ck.save(state, s)
    ck.wait()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2
    assert latest_step(str(tmp_path)) == 4


def test_restart_bitwise_identical(tmp_path):
    """Train 12 steps straight vs 6 + crash + resume 6: identical params."""
    cfg = get_smoke_config("qwen3-1.7b")
    data = TokenDataset(cfg.vocab_size, 32, 4, seed=0)
    settings = TrainSettings(remat=False, warmup=2, total_steps=12)

    def batch_fn(step):
        return {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}

    # uninterrupted
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, settings))
    for s in range(12):
        state, _ = step_fn(state, batch_fn(s))
    ref = jax.tree.map(np.asarray, state["params"])

    # interrupted at step 6 (checkpoint every 3), then a fresh manager resumes
    d = str(tmp_path / "ck")
    mgr = RestartManager(d, save_every=3)
    st2 = init_train_state(cfg, jax.random.PRNGKey(0))
    st2, _ = mgr.run(st2, step_fn, batch_fn, num_steps=6)
    del st2  # "crash"

    mgr2 = RestartManager(d, save_every=3)
    st3 = init_train_state(cfg, jax.random.PRNGKey(0))
    st3, start = mgr2.maybe_restore(st3)
    assert start == 6
    st3, _ = mgr2.run(st3, step_fn, batch_fn, num_steps=12, start_step=start)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(st3["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_watchdog_flags_slow_steps():
    wd = StragglerWatchdog(threshold=3.0)
    hits = []
    wd.on_straggler = lambda step, ratio: hits.append((step, ratio))
    for s in range(10):
        wd.observe(s, 0.1)
    assert not wd.flagged
    wd.observe(10, 0.45)
    assert wd.flagged == [10]
    assert hits and hits[0][1] > 3.0


ELASTIC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P, AxisType
    from repro.ckpt import restore_checkpoint, save_checkpoint

    ckpt = sys.argv[1]
    state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    save_checkpoint(ckpt, state, 1)          # written "on 1 device"
    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    restored, step = restore_checkpoint(ckpt, state, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    print("ELASTIC_OK")
""")


def test_elastic_reshard_1_to_8_devices(tmp_path):
    """A checkpoint written unsharded restores onto an 8-device mesh."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT, str(tmp_path / "ck")],
        capture_output=True, text=True, env=env, cwd=os.getcwd(),
        timeout=120,
    )
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]
