"""Training-loop and serving invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.tokens import TokenDataset
from repro.optim import adafactor, adamw, clip_by_global_norm, warmup_cosine
from repro.serve.engine import Request, ServeEngine
from repro.serve.steps import init_cache, make_decode_step, make_prefill_step
from repro.train.step import (
    TrainSettings, cast_for_compute, init_train_state, make_train_step,
)


def test_loss_decreases_on_markov_data():
    """Loss must drop well below the unigram entropy floor (ln 256 = 5.55):
    the model is learning the bigram structure, not just marginals."""
    cfg = get_smoke_config("qwen3-1.7b")
    data = TokenDataset(cfg.vocab_size, 64, 16, seed=0)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(
        cfg, TrainSettings(peak_lr=3e-2, warmup=10, total_steps=80,
                           remat=False)
    ), donate_argnums=(0,))
    losses = []
    for i in range(80):
        b = data.batch_at(i)
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.5, losses[::10]


def test_microbatch_accumulation_equivalence():
    """grad-accum over n microbatches == one big batch (same update)."""
    cfg = get_smoke_config("qwen2-7b")
    data = TokenDataset(cfg.vocab_size, 32, 8, seed=1)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}

    outs = {}
    for n in (1, 4):
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(
            cfg, TrainSettings(microbatches=n, remat=False)
        ))
        new_state, m = step(state, batch)
        outs[n] = (new_state, float(m["loss"]))
    p1 = jax.tree.leaves(outs[1][0]["params"])
    p4 = jax.tree.leaves(outs[4][0]["params"])
    for a, b in zip(p1, p4):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-2, atol=2e-4,
        )
    assert outs[1][1] == pytest.approx(outs[4][1], rel=2e-3)


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(250.0), rel=1e-5)
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-4)
    # under the threshold: unchanged
    same, _ = clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(g["a"]))


@pytest.mark.parametrize("make_opt", [adamw, adafactor])
def test_optimizer_decreases_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.array([5.0, -3.0, 2.0], jnp.float32)}
    state = opt.init(params)
    step = jnp.zeros((), jnp.int32)
    for i in range(60):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, state = opt.update(grads, state, params, 0.1, step + i)
    assert float(jnp.sum(params["w"] ** 2)) < 1.0


def test_adafactor_state_is_factored():
    opt = adafactor()
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((7,))}
    st = opt.init(params)
    assert st["f"]["w"]["vr"].shape == (64,)
    assert st["f"]["w"]["vc"].shape == (32,)
    assert st["f"]["b"]["v"].shape == (7,)


def test_warmup_cosine_schedule():
    lr0 = float(warmup_cosine(jnp.array(0), peak_lr=1.0, warmup=10, total=100))
    lr10 = float(warmup_cosine(jnp.array(10), peak_lr=1.0, warmup=10, total=100))
    lr99 = float(warmup_cosine(jnp.array(99), peak_lr=1.0, warmup=10, total=100))
    assert lr0 < 0.2
    assert lr10 == pytest.approx(1.0, rel=1e-3)
    assert lr99 == pytest.approx(0.1, abs=0.02)  # 10% floor at end of decay


# ------------------------------------------------------------------ serving


def test_decode_consistent_with_teacher_forcing():
    """Greedy decode through the KV cache must reproduce the argmax chain of
    a full teacher-forced forward pass (cache correctness invariant)."""
    from repro.models.transformer import forward

    cfg = get_smoke_config("qwen3-1.7b")
    params = init_train_state(cfg, jax.random.PRNGKey(7))["params"]
    B, S, N = 2, 16, 6
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

    # path A: prefill + N greedy decode steps
    cache = init_cache(cfg, B, S + N)
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))
    tok, cache = prefill(params, cache, {"tokens": prompt})
    toks_a = [np.asarray(tok)]
    seq = jnp.concatenate([prompt, tok[:, None]], 1)
    for i in range(N - 1):
        tok, cache = decode(
            params, cache, tok[:, None], jnp.array(S + i, jnp.int32)
        )
        toks_a.append(np.asarray(tok))
        seq = jnp.concatenate([seq, tok[:, None]], 1)

    # path B: teacher-forced full forwards over the same prefix
    toks_b = []
    cur = prompt
    for i in range(N):
        logits, _, _ = forward(params, cfg, tokens=cur, mode="train")
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        toks_b.append(np.asarray(nxt))
        cur = jnp.concatenate([cur, nxt[:, None]], 1)

    agree = np.mean([np.mean(a == b) for a, b in zip(toks_a, toks_b)])
    assert agree >= 0.9, (toks_a, toks_b)


def test_serve_engine_completes_requests():
    cfg = get_smoke_config("qwen3-1.7b")
    params = cast_for_compute(
        init_train_state(cfg, jax.random.PRNGKey(0))["params"]
    )
    engine = ServeEngine(cfg, params, batch_slots=2, max_seq=64)
    rng = np.random.default_rng(0)
    for rid in range(4):
        engine.submit(Request(
            rid, rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
            max_new_tokens=4,
        ))
    stats = engine.run(max_steps=64)
    assert stats["requests"] == 4
    assert stats["tokens"] == 16
    assert stats["tok_per_s"] > 0


def test_sliding_window_cache_is_bounded():
    cfg = get_smoke_config("mixtral-8x7b")
    assert cfg.sliding_window > 0
    cache = init_cache(cfg, 2, 4 * cfg.sliding_window)
    k = jax.tree.leaves(cache)[0]
    assert k.shape[2] == cfg.sliding_window  # rolling window, not full seq


def test_int8_kv_cache_attention_close_to_bf16():
    """Quantized-cache attention == full-precision within int8 error."""
    from repro.models import attention as attn

    rng = np.random.default_rng(0)
    B, T, K, D, H = 2, 32, 4, 16, 8
    q = jnp.asarray(rng.normal(size=(B, 1, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, K, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, K, D)), jnp.float32)
    idx = jnp.array(T - 1, jnp.int32)

    ref = attn.decode_attention(q, k, v, idx)
    kq, ks = attn.quantize_kv(k)
    vq, vs = attn.quantize_kv(v)
    out = attn.decode_attention_tree(
        q, {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}, idx
    )
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 0.05, err


def test_int8_kv_cache_end_to_end_decode():
    """qwen1.5 smoke (int8 cache): prefill+decode round-trips, cache dtypes
    are int8 + fp32 scales, and greedy decode mostly agrees with the
    teacher-forced argmax chain (quantization may flip rare near-ties)."""
    from repro.models.transformer import forward

    cfg = get_smoke_config("qwen1.5-32b")
    assert cfg.kv_cache_dtype == "int8"
    params = init_train_state(cfg, jax.random.PRNGKey(3))["params"]
    B, S, N = 2, 16, 5
    rng = np.random.default_rng(1)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    cache = init_cache(cfg, B, S + N)
    leaves = {l.dtype for l in jax.tree.leaves(cache)}
    assert np.dtype(np.int8) in leaves and np.dtype(np.float32) in leaves

    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))
    tok, cache = prefill(params, cache, {"tokens": prompt})
    toks_a = [np.asarray(tok)]
    for i in range(N - 1):
        tok, cache = decode(
            params, cache, tok[:, None], jnp.array(S + i, jnp.int32)
        )
        toks_a.append(np.asarray(tok))

    toks_b = []
    cur = prompt
    for i in range(N):
        logits, _, _ = forward(params, cfg, tokens=cur, mode="train")
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        toks_b.append(np.asarray(nxt))
        cur = jnp.concatenate([cur, nxt[:, None]], 1)
    agree = np.mean([np.mean(a == b) for a, b in zip(toks_a, toks_b)])
    assert agree >= 0.7, (agree, toks_a, toks_b)
