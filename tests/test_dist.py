"""Distribution substrate: axis rules, shape-fitted shardings, gradient
compression, pipeline parallelism, HLO cost model."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.dist import compression
from repro.dist.sharding import AxisRules, DEFAULT_RULES

HSET = settings(max_examples=25, deadline=None)


# ------------------------------------------------------------- axis rules


class _FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)

    @property
    def size(self):
        n = 1
        for v in self.shape.values():
            n *= v
        return n


def test_rules_resolve_basic():
    mesh = _FakeMesh({"data": 16, "model": 16})
    spec = DEFAULT_RULES.resolve(("batch", None, "tp"), mesh)
    assert tuple(spec) == ("data", None, "model")


def test_rules_resolve_multipod_batch():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    spec = DEFAULT_RULES.resolve(("batch",), mesh)
    assert tuple(spec) == (("pod", "data"),)


def test_rules_drop_missing_axes():
    mesh = _FakeMesh({"data": 4})
    spec = DEFAULT_RULES.resolve(("batch", "tp"), mesh)
    assert tuple(spec) == ("data",)  # model axis absent -> replicated


def test_rules_no_axis_reuse():
    rules = AxisRules({"a": "model", "b": "model"})
    mesh = _FakeMesh({"model": 4})
    spec = rules.resolve(("a", "b"), mesh)
    assert tuple(spec) == ("model",)  # second use dropped


@given(
    dims=st.lists(st.integers(1, 64), min_size=1, max_size=4),
    seed=st.integers(0, 100),
)
@HSET
def test_fit_pspec_always_divisible(dims, seed):
    """Property: fitted specs never assign a mesh axis that does not divide."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import fit_pspec

    mesh = _FakeMesh({"data": 4, "model": 8})
    rng = np.random.default_rng(seed)
    logical = [
        rng.choice(["batch", "fsdp", "tp", None]) for _ in dims
    ]
    spec = DEFAULT_RULES.resolve(logical, mesh)
    fitted = fit_pspec(tuple(dims), spec, mesh)
    for dim, entry in zip(dims, tuple(fitted) + (None,) * len(dims)):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else entry
        prod = 1
        for a in axes:
            prod *= mesh.shape[a]
        assert dim % prod == 0


# ---------------------------------------------------------- compression


@given(
    n=st.integers(1, 5000),
    scale=st.floats(1e-4, 1e3),
    seed=st.integers(0, 2**31),
)
@HSET
def test_int8_roundtrip_error_bound(n, scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
    y = compression.roundtrip(x)
    # symmetric int8: error <= scale_b / 2 = max|block| / 254
    err = np.abs(np.asarray(y - x))
    bound = np.abs(np.asarray(x)).max() / 200.0 + 1e-9
    assert err.max() <= bound


def test_quantize_shapes():
    x = jnp.ones((300,), jnp.float32)
    q, s = compression.quantize(x)
    assert q.shape == (3, 128) and q.dtype == jnp.int8
    assert s.shape == (3,)


def test_wire_bytes_ratio():
    w = compression.wire_bytes(1_000_000, group=2)
    assert w["ratio"] > 1.5  # compressed beats bf16 ring all-reduce


def test_compressed_psum_matches_psum_single_device():
    """On a 1-device axis compressed_psum == identity (up to quantization)."""
    mesh = jax.make_mesh(
        (1,), ("x",), axis_types=(jax.sharding.AxisType.Auto,)
    )
    x = jnp.asarray(np.random.default_rng(0).normal(size=(256,)), jnp.float32)
    fn = compression.make_compressed_allreduce(mesh, "x")
    y = fn(x)
    atol = float(np.abs(np.asarray(x)).max()) / 100.0  # int8 quantization
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=atol)


MULTIDEV_PSUM = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, AxisType
    from repro.dist.compression import compressed_psum

    mesh = jax.make_mesh((8,), ("x",), axis_types=(AxisType.Auto,))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 512)), jnp.float32)

    ref = jax.shard_map(lambda v: jax.lax.psum(v, "x"), mesh=mesh,
                        in_specs=P("x"), out_specs=P("x"))(x)
    got = jax.shard_map(lambda v: compressed_psum(v, "x"), mesh=mesh,
                        in_specs=P("x"), out_specs=P("x"))(x)
    err = np.abs(np.asarray(ref) - np.asarray(got)).max()
    rel = err / (np.abs(np.asarray(ref)).max() + 1e-9)
    assert rel < 0.05, rel
    print("PSUM_OK", rel)
""")


def test_compressed_psum_multidevice_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", MULTIDEV_PSUM], capture_output=True,
        text=True, env=env, cwd=os.getcwd(), timeout=180,
    )
    assert "PSUM_OK" in out.stdout, out.stderr[-2000:]


PIPELINE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import AxisType
    from repro.dist.pipeline import bubble_fraction, pipeline_apply

    mesh = jax.make_mesh((4,), ("pipe",), axis_types=(AxisType.Auto,))
    P_stages, M, mb, d = 4, 8, 2, 16
    rng = np.random.default_rng(0)
    Ws = jnp.asarray(rng.normal(size=(P_stages, d, d)) * 0.3, jnp.float32)
    x = jnp.asarray(rng.normal(size=(M, mb, d)), jnp.float32)

    def stage_fn(W, h):
        return jnp.tanh(h @ W)

    out = pipeline_apply(stage_fn, Ws, x, mesh=mesh, axis="pipe")

    # reference: sequential through all stages
    ref = x
    for s in range(P_stages):
        ref = jnp.tanh(ref @ Ws[s])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)

    # the schedule must lower to collective-permute
    lowered = jax.jit(lambda w, v: pipeline_apply(
        stage_fn, w, v, mesh=mesh, axis="pipe")).lower(Ws, x)
    txt = lowered.compile().as_text()
    assert "collective-permute" in txt
    assert abs(bubble_fraction(8, 4) - 3/11) < 1e-9
    print("PIPE_OK")
""")


def test_pipeline_parallelism_subprocess():
    """GPipe schedule == sequential reference; lowers to collective-permute."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", PIPELINE_SCRIPT], capture_output=True,
        text=True, env=env, cwd=os.getcwd(), timeout=240,
    )
    assert "PIPE_OK" in out.stdout, out.stderr[-2000:]


# ------------------------------------------------------------ hlo cost model


SYNTH_HLO = textwrap.dedent("""
    HloModule synth

    %body (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
      %p = (s32[], f32[64,64]) parameter(0)
      %iv = s32[] get-tuple-element(%p), index=0
      %one = s32[] constant(1)
      %next = s32[] add(%iv, %one)
      %x = f32[64,64] get-tuple-element(%p), index=1
      %y = f32[64,64] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[64,64] all-reduce(%y), replica_groups=[2,4]<=[8], to_apply=%sum
      ROOT %t = (s32[], f32[64,64]) tuple(%next, %ar)
    }

    %cond (pc: (s32[], f32[64,64])) -> pred[] {
      %pc = (s32[], f32[64,64]) parameter(0)
      %ivc = s32[] get-tuple-element(%pc), index=0
      %lim = s32[] constant(12)
      ROOT %lt = pred[] compare(%ivc, %lim), direction=LT
    }

    %sum (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (arg: f32[64,64]) -> (s32[], f32[64,64]) {
      %arg = f32[64,64] parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[64,64]) tuple(%zero, %arg)
      ROOT %w = (s32[], f32[64,64]) while(%init), condition=%cond, body=%body
    }
""")


def test_hlo_cost_loop_exact_flops_and_collectives():
    from repro.launch import hlo_cost

    rep = hlo_cost.analyze(SYNTH_HLO, total_devices=8)
    # 12 iterations x 2*64*64*64 flops
    assert rep.flops == pytest.approx(12 * 2 * 64**3)
    ar = rep.coll_by_kind["all-reduce"]
    assert ar["count"] == 12
    # ring all-reduce over group of 4: 2 * bytes * 3/4 per device per iter
    per = 2 * (64 * 64 * 4) * (3 / 4)
    assert ar["wire_bytes"] == pytest.approx(12 * per)
    assert rep.unknown_loops == 0


def test_hlo_cost_known_trip_count_annotation():
    from repro.launch import hlo_cost

    txt = SYNTH_HLO.replace(
        "condition=%cond, body=%body",
        'condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}',
    )
    rep = hlo_cost.analyze(txt, total_devices=8)
    assert rep.flops == pytest.approx(5 * 2 * 64**3)


def test_hlo_cost_on_real_scan_module():
    """End-to-end: a jitted lax.scan matmul counts trip_count x body flops."""
    from repro.launch import hlo_cost

    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c), None

        out, _ = jax.lax.scan(body, x, None, length=9)
        return out

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32)
    ).compile()
    rep = hlo_cost.analyze(compiled.as_text(), total_devices=1)
    assert rep.flops == pytest.approx(9 * 2 * 32**3, rel=0.01)


# ------------------------------------------------------------- multi-host


def test_detect_cluster_env_forms(monkeypatch):
    from repro.launch.multihost import detect_cluster, host_batch_slice

    monkeypatch.setenv("REPRO_NUM_PROC", "4")
    monkeypatch.setenv("REPRO_PROC_ID", "2")
    monkeypatch.setenv("REPRO_COORD_ADDR", "h0:1234")
    info = detect_cluster()
    assert (info.process_id, info.num_processes) == (2, 4)
    assert info.coordinator == "h0:1234"
    assert host_batch_slice(256, info) == slice(128, 192)

    monkeypatch.delenv("REPRO_NUM_PROC")
    monkeypatch.delenv("REPRO_PROC_ID")
    monkeypatch.setenv("SLURM_NTASKS", "8")
    monkeypatch.setenv("SLURM_PROCID", "3")
    monkeypatch.setenv("SLURM_NODELIST", "tpu[0-7]")
    info = detect_cluster()
    assert (info.process_id, info.num_processes) == (3, 8)


def test_host_sharded_data_covers_global_batch():
    """Union of per-host TokenDataset batches == a single-host batch."""
    from repro.data.tokens import TokenDataset
    from repro.launch.multihost import HostInfo, host_batch_slice

    full = TokenDataset(128, 16, 8, seed=5).batch_at(3)["tokens"]
    parts = []
    for pid in range(4):
        d = TokenDataset(128, 16, 8, seed=5, host_id=pid, num_hosts=4)
        parts.append(d.batch_at(3)["tokens"])
    # hosts produce disjoint deterministic rows; together they cover a
    # global batch of the same shape (content differs from the 1-host
    # stream by construction — each host seeds with its host_id)
    stacked = np.concatenate(parts, 0)
    assert stacked.shape == full.shape
    assert len({arr.tobytes() for arr in parts}) == 4  # all distinct
