"""Edge cases for the Table-2 / Fig-7 metrics (f1_score, v_measure).

The DSE loop feeds these metrics whatever a candidate model emits — a
degenerate model collapsing to one class, an empty evaluation slice, a
class missing from both y_true and y_pred — and a NaN here poisons the BO's
regret bookkeeping silently (NaN propagates through max()).  Degenerate
inputs must score 0.0, never divide by zero.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.mlalgos import accuracy, evaluate_metric, f1_score, v_measure

HSET = settings(max_examples=25, deadline=None)


# ---------------------------------------------------------------- f1_score


def test_f1_empty_arrays_is_zero_not_nan():
    assert f1_score(np.array([]), np.array([]), num_classes=2) == 0.0
    assert accuracy(np.array([]), np.array([])) == 0.0
    assert evaluate_metric("f1", [], [], num_classes=2) == 0.0


def test_f1_empty_positive_class():
    # binary F1 scores class 1; no positives anywhere -> 0, not 0/0
    y = np.zeros(8, np.int32)
    assert f1_score(y, y, num_classes=2) == 0.0
    # positives exist in y_true but the model never predicts them
    y_true = np.array([0, 0, 1, 1])
    assert f1_score(y_true, np.zeros(4, np.int32), num_classes=2) == 0.0
    # model predicts positives that never occur
    assert f1_score(np.zeros(4, np.int32), y_true, num_classes=2) == 0.0


def test_f1_all_one_class_predictions_multiclass():
    y_true = np.array([0, 1, 2, 0, 1, 2])
    y_pred = np.zeros(6, np.int32)
    got = f1_score(y_true, y_pred, num_classes=3)
    # class 0: prec 2/6, rec 2/2 -> f1 = 0.5; classes 1, 2: 0
    assert got == pytest.approx(0.5 / 3)


def test_f1_multiclass_with_missing_class():
    # num_classes=4 but class 3 absent from y_true AND y_pred: it must
    # contribute 0 to the macro mean (sklearn zero_division=0), not NaN
    y_true = np.array([0, 1, 2, 0, 1, 2])
    y_pred = np.array([0, 1, 2, 0, 1, 2])
    assert f1_score(y_true, y_pred, num_classes=4) == pytest.approx(3 / 4)
    assert f1_score(y_true, y_pred, num_classes=3) == pytest.approx(1.0)


def test_f1_perfect_binary():
    y = np.array([0, 1, 1, 0, 1])
    assert f1_score(y, y, num_classes=2) == 1.0


@given(n=st.integers(1, 40), seed=st.integers(0, 2**31))
@HSET
def test_f1_always_finite_in_unit_interval(n, seed):
    rng = np.random.default_rng(seed)
    for c in (2, 3, 5):
        y_true = rng.integers(0, c, n)
        y_pred = rng.integers(0, c, n)
        got = f1_score(y_true, y_pred, num_classes=c)
        assert np.isfinite(got) and 0.0 <= got <= 1.0


# --------------------------------------------------------------- v_measure


def test_v_measure_empty_is_zero_not_nan():
    assert v_measure(np.array([]), np.array([])) == 0.0


def test_v_measure_single_cluster_and_single_class():
    labels = np.array([0, 0, 1, 1])
    # everything in one cluster: homogeneity collapses -> 0
    assert v_measure(labels, np.zeros(4, np.int32)) == 0.0
    # one label class, clusters split it: completeness collapses -> 0
    assert v_measure(np.zeros(4, np.int32), np.array([0, 1, 0, 1])) == 0.0
    # one class AND one cluster: both entropies vanish -> perfect (1.0)
    assert v_measure(np.zeros(4, np.int32), np.zeros(4, np.int32)) == 1.0


def test_v_measure_perfect_clustering():
    labels = np.array([0, 0, 1, 1, 2, 2])
    clusters = np.array([2, 2, 0, 0, 1, 1])  # same partition, renamed ids
    assert v_measure(labels, clusters) == pytest.approx(1.0)


@given(n=st.integers(1, 40), seed=st.integers(0, 2**31))
@HSET
def test_v_measure_finite_and_permutation_invariant(n, seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 3, n)
    clusters = rng.integers(0, 4, n)
    got = v_measure(labels, clusters)
    assert np.isfinite(got) and 0.0 <= got <= 1.0 + 1e-12
    # relabeling cluster ids must not change the score
    perm = rng.permutation(5)
    assert v_measure(labels, perm[clusters]) == pytest.approx(got)
