"""Stage IR, whole-DAG compilation (gating semantics), packet engine,
natural DSL chaining, and IR-routed resource accounting."""

import numpy as np
import pytest

from repro.core import chaining, codegen, feasibility as feas, mlalgos
from repro.core import stageir
from repro.core.alchemy import Model, Par, Seq
from repro.data import netdata
from repro.serve.packet_engine import PacketServeEngine


def _report():
    return feas.FeasibilityReport(True, [], {"cu": 1}, 1.0, 1e9)


def _leaf(name):
    return Model({"name": name, "data_loader": lambda: None,
                  "algorithm": None})


@pytest.fixture(scope="module")
def small_data():
    return netdata.make_ad_dataset(features=7, n_train=1024, n_test=512)


@pytest.fixture(scope="module")
def pipes(small_data):
    d = small_data
    dnn = mlalgos.train_dnn(d, hidden=[8], epochs=3, seed=0)
    svm = mlalgos.train_svm(d, epochs=4, seed=0)
    km = mlalgos.train_kmeans(d, k=3, seed=0)
    tree = mlalgos.train_tree(d, max_depth=4, seed=0)
    return {
        "dnn": codegen.taurus_codegen("dnn", dnn, _report()),
        "svm": codegen.mat_codegen("svm", svm, _report(), d.train_x),
        "km": codegen.taurus_codegen("km", km, _report()),
        "tree": codegen.mat_codegen("tree", tree, _report(), d.train_x),
    }


# ------------------------------------------------------------- stage IR


def test_every_backend_lowers_to_stages(pipes):
    assert [s.kind for s in pipes["dnn"].stages] == ["fused_mlp", "reduce"]
    assert [s.kind for s in pipes["svm"].stages] == [
        "quantize", "lut_gather", "reduce", "label_map"
    ]
    assert [s.kind for s in pipes["km"].stages] == [
        "centroid_distance", "reduce", "label_map"
    ]
    assert [s.kind for s in pipes["tree"].stages] == ["tree_traverse"]


def test_stage_pipelines_verify(pipes, small_data):
    X = small_data.test_x
    assert pipes["dnn"].verify(X) == 0.0
    assert pipes["km"].verify(X) == 0.0
    # tree stage walk is exact (f32 thresholds both sides)
    assert pipes["tree"].verify(X) == 0.0
    assert pipes["svm"].verify(X, max_mismatch_frac=0.03) <= 0.03


def test_fuse_peephole_produces_fused_classify(pipes):
    fused = stageir.fuse_pipeline_stages(pipes["dnn"].stages)
    assert [s.kind for s in fused] == ["fused_classify"]
    # non-matching lists pass through untouched
    same = stageir.fuse_pipeline_stages(pipes["km"].stages)
    assert [s.kind for s in same] == [s.kind for s in pipes["km"].stages]


def test_fused_classify_matches_unfused(pipes, small_data):
    import jax.numpy as jnp

    X = jnp.asarray(small_data.test_x[:300])
    plain = stageir.apply_stages(pipes["dnn"].stages, X)
    fused = stageir.apply_stages(
        stageir.fuse_pipeline_stages(pipes["dnn"].stages), X
    )
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(fused))


def test_stage_summary_counts_params(pipes):
    s = pipes["dnn"].stage_summary()
    assert s["params"] == pipes["dnn"].model.param_count
    assert s["macs"] > 0


# -------------------------------------------- whole-DAG jit == eager numpy


DAGS = {
    "seq_gate": lambda a, b, c: a > b > c,
    "par": lambda a, b, c: a | b | c,
    "seq_of_par": lambda a, b, c: a > (b | c),
    "par_of_seq": lambda a, b, c: (a > b) | c,
}


@pytest.mark.parametrize("shape", sorted(DAGS))
@pytest.mark.parametrize("combine", ["or", "and"])
def test_compiled_dag_matches_eager_bitwise(pipes, small_data, shape,
                                            combine):
    """Masked (jnp.where) short-circuit == eager numpy gating, bit-for-bit,
    across mixed taurus/MAT backends."""
    node = DAGS[shape](_leaf("dnn"), _leaf("svm"), _leaf("km"))
    X = small_data.test_x[:256]
    eager = chaining.run_dag(node, pipes, X, combine=combine)
    compiled = chaining.compile_dag(node, pipes, combine=combine)
    np.testing.assert_array_equal(eager, compiled(X))


def test_seq_gating_short_circuits(pipes, small_data):
    """Packets flagged by the gate keep its verdict even when the second
    model disagrees."""
    node = _leaf("dnn") > _leaf("km")
    X = small_data.test_x[:512]
    gate = np.asarray(pipes["dnn"](X))
    second = np.asarray(pipes["km"](X))
    out = chaining.run_dag(node, pipes, X)
    flagged = gate > 0
    np.testing.assert_array_equal(out[flagged], gate[flagged])
    np.testing.assert_array_equal(out[~flagged], second[~flagged])
    compiled = chaining.compile_dag(node, pipes)
    np.testing.assert_array_equal(out, compiled(X))


def test_compiled_dag_concat_combine(pipes, small_data):
    node = _leaf("dnn") | _leaf("km")
    X = small_data.test_x[:128]
    eager = chaining.run_dag(node, pipes, X, combine="concat")
    compiled = chaining.compile_dag(node, pipes, combine="concat")
    assert eager.shape == (128, 2)
    np.testing.assert_array_equal(eager, compiled(X))


def test_run_dag_rejects_unknown_combine(pipes, small_data):
    with pytest.raises(KeyError):
        chaining.run_dag(_leaf("dnn") | _leaf("km"), pipes,
                         small_data.test_x[:8], combine="xor")


# --------------------------------------------------------- natural chaining
#
# Natural (un-parenthesized) chaining depends on CPython bytecode rails;
# on interpreters where the import-time self-checks fail these tests are
# skipped — the DSL warns there and the parenthesized form stays correct.

from repro.core.alchemy import CHAIN_RAILS_OK, NATURAL_CHAINS_OK  # noqa: E402

natural_chains = pytest.mark.skipif(
    not (NATURAL_CHAINS_OK and CHAIN_RAILS_OK),
    reason="interpreter defeats chained-comparison interception",
)


@natural_chains
def test_natural_chain_three_models():
    a, b, c = _leaf("a"), _leaf("b"), _leaf("c")
    seq = a > b > c
    assert isinstance(seq, Seq)
    assert seq.describe() == "a > b > c"


@natural_chains
def test_natural_chain_four_and_mixed():
    # NB: chains are built in plain statements — pytest's assertion
    # rewriter re-orders chained-comparison evaluation inside ``assert``
    # expressions, which defeats the __bool__ interception
    a, b, c, d = (_leaf(n) for n in "abcd")
    four = a > b > c > d
    assert four.describe() == "a > b > c > d"
    mid_par = a > (b | c) > d
    assert mid_par.describe() == "a > (b | c) > d"
    front_par = (a | b) > c > d
    assert front_par.describe() == "(a | b) > c > d"
    # parenthesized style keeps working
    parens = ((a > b) > c) > d
    assert parens.describe() == "a > b > c > d"


@natural_chains
def test_natural_chain_trailing_par():
    a, b, c, d = (_leaf(n) for n in "abcd")
    # the Par is evaluated mid-chain, between Seq.__bool__ and the
    # extending __gt__ — must not disturb the pending record
    chain = a > b > (c | d)
    assert chain.describe() == "a > b > (c | d)"


@natural_chains
def test_natural_chain_no_cross_statement_pollution():
    a, b, c, d = (_leaf(n) for n in "abcd")
    s = a > b
    if s:  # truth-test of a BOUND Seq must not record a chain ...
        pass
    u = b > c  # ... even when the next composition reuses its last operand
    assert u.describe() == "b > c"
    v = c > d  # disjoint operands stay clean too
    assert v.describe() == "c > d"
    assert s.describe() == "a > b"


@natural_chains
def test_natural_chain_if_temporary_not_polluting():
    # truth-testing a TEMPORARY Seq in an `if` is a user truth-test, not a
    # chain (POP_JUMP opcode, not the chain's JUMP_IF_*_OR_POP)
    a, b, c = (_leaf(n) for n in "abc")
    if a > b:
        pass
    u = b > c
    assert u.describe() == "b > c"


@natural_chains
def test_natural_chain_nested_seq_operand():
    # the inner (c > d) runs between the chain record and the extending
    # __gt__; a mismatching composition must not destroy the chain head
    a, b, c, d = (_leaf(n) for n in "abcd")
    chain = a > b > (c > d)
    assert chain.describe() == "a > b > (c > d)"


@natural_chains
def test_natural_chain_nested_chain_operand():
    # the inner operand is ITSELF a chain — its record must stack on top
    # of (not replace) the outer one
    a, b, c, d, e = (_leaf(n) for n in "abcde")
    chain = a > b > (c > d > e)
    assert chain.describe() == "a > b > (c > d > e)"
    assert [m.name for m in chain.leaves()] == list("abcde")


@natural_chains
def test_natural_chain_thread_isolation():
    import threading

    results = {}

    def build(key):
        x, y, z = (_leaf(f"{key}{i}") for i in range(3))
        for _ in range(200):
            chain = x > y > z
            assert len(chain.children) == 3
        results[key] = chain.describe()

    threads = [threading.Thread(target=build, args=(k,)) for k in "pq"]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert results == {"p": "p0 > p1 > p2", "q": "q0 > q1 > q2"}


@natural_chains
def test_natural_chain_and_expression_not_polluting():
    # a value-producing `and` shares the chain's JUMP opcode, but jumps to
    # the expression end rather than a chain cleanup block — it must never
    # record, neither leaking into a later `>` ...
    m1, m2, m3 = (_leaf(n) for n in ("m1", "m2", "m3"))
    enabled = True
    gate = (m1 > m2) and enabled
    chain = m2 > m3
    assert chain.describe() == "m2 > m3"
    assert gate is True
    # ... nor splicing into a composition evaluated INSIDE the `and`
    ident = lambda node: node  # noqa: E731
    t = (m1 > m2) and ident(m2 > m3)
    assert t.describe() == "m2 > m3"


def test_natural_chain_selfcheck_flag():
    from repro.core import alchemy

    assert alchemy.NATURAL_CHAINS_OK


# ------------------------------------------------------------ packet engine


def test_packet_engine_matches_direct_call(pipes, small_data):
    X = small_data.test_x[:500]
    eng = PacketServeEngine(pipes["dnn"], feature_dim=7, max_batch=128)
    # ragged submits, arrival order preserved across micro-batches
    eng.submit(X[:37])
    eng.submit(X[37:290])
    eng.submit(X[290:])
    out = eng.flush()
    np.testing.assert_array_equal(out, np.asarray(pipes["dnn"](X)))
    stats = eng.stats()
    assert stats["packets"] == 500
    assert stats["batches"] == 4          # ceil(500/128)
    assert stats["pad_packets"] == 4 * 128 - 500
    assert eng.pending == 0


def test_packet_engine_serves_compiled_dag(pipes, small_data):
    node = _leaf("dnn") > (_leaf("svm") | _leaf("km"))
    dag = chaining.compile_dag(node, pipes)
    X = small_data.test_x[:300]
    eng = PacketServeEngine(dag, feature_dim=7, max_batch=100)
    chunks = [X[i:i + 61] for i in range(0, 300, 61)]
    got = np.concatenate(list(eng.serve_stream(chunks)))
    np.testing.assert_array_equal(got, chaining.run_dag(node, pipes, X))


def test_packet_engine_rejects_wrong_width():
    eng = PacketServeEngine(
        lambda x: np.zeros(len(x), np.int32), feature_dim=7, max_batch=8
    )
    with pytest.raises(ValueError):
        eng.submit(np.zeros((4, 5), np.float32))


# --------------------------------------------- accounting reads the IR


def test_topology_params_matches_trained_counts(pipes):
    for key in ("dnn", "svm"):
        tm = pipes[key].model
        assert feas.topology_params(tm.algorithm, tm.topology) \
            == tm.param_count


def test_spec_layers_drive_taurus_estimate():
    specs = stageir.lower_topology("dnn", {"widths": [7, 16, 2]})
    assert stageir.spec_layers(specs) == [(7, 16), (16, 2)]
    specs = stageir.lower_topology("kmeans", {"k": 5, "n_features": 3})
    assert stageir.spec_layers(specs) == [(3, 5)]


def test_mat_specs_drive_table_counts():
    m = feas.MATModel()
    # same numbers as the IIsy rules, now read off the MAT stage specs
    assert m.mats_for("kmeans", {"k": 5, "n_features": 7}) == 5
    assert m.mats_for("svm", {"n_features": 7, "n_classes": 3}) == 7
    assert m.mats_for("tree", {"nodes": [{}] * 31, "depth": 4}) == 4
    assert m.mats_for("dnn", {"widths": [7, 10, 10, 5, 2]}) == 48


def test_dag_stage_summary_dedups_shared_models(pipes):
    a = _leaf("dnn")
    node = (a > a) > a
    s = chaining.dag_stage_summary(node, pipes)
    assert s["params"] == pipes["dnn"].model.param_count  # counted once


# ------------------------------------------------------------------ fusion


def test_fused_model_task_pipeline_via_ir(small_data):
    from repro.core import fusion

    p1, p2 = small_data.split_half()
    fm = fusion.fuse([p1, p2], hidden=[8], epochs=2)
    pipe = fm.task_pipeline(0)
    assert [s.kind for s in pipe.stages] == ["fused_mlp", "reduce"]
    assert pipe.verify(p1.test_x) == 0.0
    # per-task pipeline counts trunk + its own head, not all heads
    assert pipe.stage_summary()["params"] == pipe.model.param_count
    assert pipe.model.param_count < fm.param_count
