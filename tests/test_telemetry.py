"""Unified telemetry plane (docs/pipeline_ir.md#telemetry-contract), tier-1.

Covers the three surfaces — metrics registry, span tracer, event
journal — their exporters (Prometheus text, JSON, Chrome trace_event),
the flow-table health scans, and the engine integration properties:
counter totals equal packets served under arbitrary interleavings with
hot swaps at depth > 1, bit-identical verdicts with telemetry on/off,
and the drift -> retrain -> swap -> mitigation event trail of a
coordinated-DDoS replay."""

import json
import threading

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import stageir
from repro.flowstate import (
    MITIGATED,
    DriftDetector,
    DriftSnapshot,
    FlowStateSpec,
    MitigationSpec,
    StatefulPipeline,
)
from repro.serve import HotSwapController, PacketServeEngine
from repro.serve.packet_engine import ServeStats
from repro.telemetry import (
    EVENT_KINDS,
    EventJournal,
    Telemetry,
    Tracer,
    batch_segmentation,
    mitigation_residency,
    table_health,
    to_json,
    to_prometheus,
)
from repro.telemetry.metrics import MetricsRegistry

HSET = settings(max_examples=10, deadline=None)


# ------------------------------------------------------------------ metrics


def test_counter_gauge_histogram_record_and_snapshot():
    m = MetricsRegistry()
    c = m.counter("pkts_total", "packets")
    c.default.inc(3)
    c.inc(2, backend="pallas")
    g = m.gauge("occ", "occupancy")
    g.default.set(0.5)
    h = m.histogram("lat_ms", "latency", buckets=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        h.default.observe(v)

    snap = m.snapshot()
    assert snap["pkts_total"]["kind"] == "counter"
    vals = {tuple(v["labels"].items()): v["value"]
            for v in snap["pkts_total"]["values"]}
    assert vals[()] == 3.0
    assert vals[(("backend", "pallas"),)] == 2.0
    assert snap["occ"]["values"][0]["value"] == 0.5
    hv = snap["lat_ms"]["values"][0]
    assert [b["count"] for b in hv["buckets"]] == [1, 1, 1]
    assert hv["buckets"][-1]["le"] == float("inf")
    assert hv["count"] == 3 and hv["sum"] == 55.5
    # snapshot is a copy: later recording never mutates it
    c.default.inc(100)
    assert vals[()] == 3.0


def test_registry_get_or_create_and_kind_mismatch():
    m = MetricsRegistry()
    assert m.counter("x") is m.counter("x")
    with pytest.raises(TypeError):
        m.gauge("x")
    assert m.get("x").kind == "counter"
    assert m.get("missing") is None


def test_label_children_are_interned_handles():
    m = MetricsRegistry()
    c = m.counter("y")
    assert c.labels(backend="pallas") is c.labels(backend="pallas")
    assert c.labels(backend="pallas") is not c.labels(backend="interpret")


# ------------------------------------------------------------------- tracer


def test_tracer_ring_bound_and_chrome_trace_structure():
    tr = Tracer(capacity=4)
    for i in range(6):
        tr.record(f"s{i}", float(i), float(i) + 0.001, args={"i": i})
    assert len(tr) == 4 and tr.dropped == 2
    assert [s.name for s in tr.spans()] == ["s2", "s3", "s4", "s5"]

    ct = tr.chrome_trace()
    assert set(ct) == {"traceEvents", "displayTimeUnit", "otherData"}
    assert ct["otherData"]["dropped_spans"] == 2
    assert len(ct["traceEvents"]) == 4
    for ev in ct["traceEvents"]:
        assert ev["ph"] == "X"
        assert isinstance(ev["ts"], int) and isinstance(ev["dur"], int)
        assert ev["dur"] >= 1 and ev["pid"] == 1 and ev["tid"] >= 1
        assert isinstance(ev["name"], str) and isinstance(ev["cat"], str)
    # ts are monotonic (single-threaded recording) and JSON-clean
    ts = [e["ts"] for e in ct["traceEvents"]]
    assert ts == sorted(ts)
    json.dumps(ct)


def test_tracer_span_contextmanager_records_args():
    tr = Tracer()
    with tr.span("compile", cat="warm", backend="pallas"):
        pass
    (s,) = tr.spans()
    assert s.name == "compile" and s.cat == "warm"
    assert s.args == {"backend": "pallas"} and s.dur_s >= 0.0


# ------------------------------------------------------------------ journal


def test_journal_orders_events_and_round_trips_file(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    j = EventJournal(path)
    j.emit("drift", score=3.2)
    j.emit("hot_swap", lat_ms=1.5, pkt_offset=1024)
    j.emit("slo_gate", ok=True)
    j.close()

    evs = j.events()
    assert [e["seq"] for e in evs] == [0, 1, 2]
    ts = [e["t_s"] for e in evs]
    assert ts == sorted(ts)
    assert j.kinds() == {"drift", "hot_swap", "slo_gate"}
    assert [e["kind"] for e in j.events("drift")] == ["drift"]

    loaded = EventJournal.load(path)
    assert loaded == evs
    # dump() writes the same JSON-lines form
    assert EventJournal.load(j.dump(str(tmp_path / "d.jsonl"))) == evs


def test_journal_ring_is_bounded():
    j = EventJournal(capacity=8)
    for i in range(20):
        j.emit("drift", i=i)
    evs = j.events()
    assert len(evs) == 8 and evs[0]["i"] == 12 and evs[-1]["seq"] == 19


def test_event_kinds_vocabulary_is_stable():
    assert set(EVENT_KINDS) == {
        "drift", "retrain_start", "retrain_done", "hot_swap",
        "mitigation_engage", "mitigation_release", "backend_fallback",
        "slo_gate",
    }


# ---------------------------------------------------------------- exporters


def test_prometheus_text_format():
    m = MetricsRegistry()
    m.counter("pkts_total", "packets served").inc(5, backend="pallas")
    m.gauge("occ").default.set(0.25)
    h = m.histogram("lat_ms", "latency", buckets=(1.0, 10.0))
    h.default.observe(0.5)
    h.default.observe(5.0)
    text = to_prometheus(m.snapshot())
    assert "# HELP pkts_total packets served" in text
    assert "# TYPE pkts_total counter" in text
    assert 'pkts_total{backend="pallas"} 5' in text
    assert "occ 0.25" in text
    # histogram buckets are CUMULATIVE, +Inf closes the family
    assert 'lat_ms_bucket{le="1"} 1' in text
    assert 'lat_ms_bucket{le="10"} 2' in text
    assert 'lat_ms_bucket{le="+Inf"} 2' in text
    assert "lat_ms_sum 5.5" in text
    assert "lat_ms_count 2" in text


def test_prometheus_escapes_label_values():
    m = MetricsRegistry()
    m.counter("c").inc(1, path='a"b\\c')
    assert 'c{path="a\\"b\\\\c"} 1' in to_prometheus(m.snapshot())


def test_json_export_parses_back():
    m = MetricsRegistry()
    m.counter("c").default.inc(2)
    doc = json.loads(to_json(m.snapshot()))
    assert doc["c"]["values"][0]["value"] == 2.0


# -------------------------------------------------------------- flow health


def _spec(n_slots=16):
    return FlowStateSpec(n_slots=n_slots, n_counters=1, n_ewma=1,
                         hist_sizes=(3,), ewma_alpha=0.5)


def _flow_stages(spec):
    fk = stageir.FlowKey((0,), spec.n_slots)
    ru = stageir.RegisterUpdate(
        spec, ewma_cols=(1,), hist_cols=(1,),
        hist_edges=(np.linspace(0, 1, 4)[1:-1],),
    )
    return [fk, ru, stageir.WindowStats(spec, mode="all")]


class _FakeState:
    def __init__(self, keys):
        self.keys = np.asarray(keys, np.int32)


def test_table_health_counts_inserts_and_evictions():
    prev = np.array([-1, 5, 7, -1], np.int32)
    cur = np.array([3, 5, 9, -1], np.int32)
    h = table_health(_FakeState(cur), prev)
    assert h["slots"] == 4 and h["occupied"] == 3
    assert h["occupancy_frac"] == 0.75
    assert h["inserts"] == 1          # slot 0: empty -> 3
    assert h["evictions"] == 1        # slot 2: 7 -> 9 while occupied
    np.testing.assert_array_equal(h["keys"], cur)
    assert h["mit_slots"] == 0        # no action table


def test_mitigation_residency_counts_marked_flows():
    class S:
        mit_spec = MitigationSpec(n_slots=4, mode="drop", threshold=2)
        mit_keys = np.array([1, -1, 3, 4], np.int32)
        mit_regs = np.array([[3, 0], [9, 0], [1, 0], [2, 0]], np.float32)

    r = mitigation_residency(S())
    assert r == {"mit_slots": 4, "mit_occupied": 3, "mit_marked": 2}


def test_batch_segmentation_matches_kernel_rank_semantics():
    # chain depths: slot 3 x4, slot 5 x2, slot 9 x1
    slots = np.array([3, 5, 3, 9, 3, 5, 3])
    seg = batch_segmentation(slots, par_rounds=2)
    assert seg["n_live"] == 7
    assert seg["max_chain"] == 4
    assert seg["n_deep"] == 2         # ranks 2 and 3 of the slot-3 chain
    assert seg["drain_heavy"] is (2 * 8 > 7 * 7)
    assert batch_segmentation(np.array([]), par_rounds=2) == {
        "n_live": 0, "n_deep": 0, "max_chain": 0, "drain_heavy": False}
    # a deep single chain: 30/32 deep strictly exceeds 7/8 -> drain-heavy
    assert batch_segmentation(np.full(32, 7), par_rounds=2)[
        "drain_heavy"] is True
    # ...but exactly 7/8 deep does not (the flag's rule is strict)
    assert batch_segmentation(np.full(16, 7), par_rounds=2)[
        "drain_heavy"] is False


def test_batch_segmentation_default_par_rounds_is_kernel_constant():
    from repro.kernels.flow_update.kernel import PAR_ROUNDS

    slots = np.full(PAR_ROUNDS + 3, 1)
    assert batch_segmentation(slots)["n_deep"] == 3


# --------------------------------------------------- ServeStats (satellite)


def test_empty_serve_stats_round_trips_json_clean():
    """Regression: an engine that served nothing must report 0.0 (not
    nan) latency percentiles, and as_dict() must round-trip JSON."""
    s = ServeStats()
    d = s.as_dict()
    assert d["lat_p50_ms"] == 0.0
    assert d["lat_p95_ms"] == 0.0
    assert d["lat_p99_ms"] == 0.0
    assert d["pkt_per_s"] == 0.0
    assert json.loads(json.dumps(d)) == d
    # and a freshly constructed engine (warm-up only) is equally clean
    eng = PacketServeEngine(StatefulPipeline(_flow_stages(_spec())),
                            feature_dim=2, max_batch=8)
    d = eng.stats()
    assert d["lat_p50_ms"] == 0.0 and d["packets"] == 0
    assert json.loads(json.dumps(d)) == d


# -------------------------------------------------------- engine integration


def _flow_packets(rng, n, flows=6):
    X = np.zeros((n, 2), np.float32)
    X[:, 0] = rng.integers(0, flows, n)
    X[:, 1] = rng.random(n)
    return X


def test_engine_counters_spans_and_prometheus_end_to_end():
    rng = np.random.default_rng(0)
    eng = PacketServeEngine(StatefulPipeline(_flow_stages(_spec())),
                            feature_dim=2, max_batch=8, depth=2)
    eng.TELEMETRY_SEG_SAMPLE = 1      # exact schedule counts for the test
    tel = eng.telemetry()
    assert tel is not None
    X = _flow_packets(rng, 100)
    eng.submit(X)
    eng.flush()

    snap = tel.snapshot()
    one = {k: snap[k]["values"][0]["value"] for k in snap
           if snap[k]["kind"] in ("counter", "gauge")}
    assert one["serve_packets_total"] == 100
    assert one["serve_batches_total"] == 13   # ceil(100 / 8)
    assert one["serve_pad_packets_total"] == 13 * 8 - 100
    assert one["serve_depth"] == 2
    # every batch classified lockstep-or-drain when sampling is off
    assert (one["flow_lockstep_batches_total"]
            + one["flow_drain_batches_total"]) == 13
    # flush-boundary health scan ran against the live table
    assert one["flow_occupied_slots"] == eng.state.occupied
    # per-backend labelled counter carries the engine's actual backend
    bb = snap["serve_backend_batches_total"]["values"]
    assert {v["labels"]["backend"]: v["value"] for v in bb} == {
        eng.backend: 13}
    # histograms observed one value per batch
    assert snap["serve_dispatch_ms"]["values"][0]["count"] == 13
    assert snap["serve_batch_latency_ms"]["values"][0]["count"] == 13
    # exporters render the live registry
    assert "serve_packets_total 100" in tel.prometheus()
    assert json.loads(tel.json())["serve_packets_total"]
    # the trace has warm-up + dispatch + batch spans, Chrome-valid
    names = {s.name for s in tel.tracer.spans()}
    assert {"warm_up", "dispatch", "batch"} <= names
    for ev in tel.chrome_trace()["traceEvents"]:
        assert ev["ph"] == "X" and ev["dur"] >= 1


def test_telemetry_false_disables_recording_and_keeps_verdicts():
    rng = np.random.default_rng(1)
    X = _flow_packets(rng, 60)
    eng_off = PacketServeEngine(StatefulPipeline(_flow_stages(_spec())),
                                feature_dim=2, max_batch=8,
                                telemetry=False)
    eng_on = PacketServeEngine(StatefulPipeline(_flow_stages(_spec())),
                               feature_dim=2, max_batch=8)
    assert eng_off.telemetry() is None
    eng_off.submit(X)
    eng_on.submit(X)
    np.testing.assert_array_equal(eng_off.flush(), eng_on.flush())


def test_shared_plane_aggregates_across_engines():
    tel = Telemetry()
    rng = np.random.default_rng(2)
    X = _flow_packets(rng, 40)
    for _ in range(2):
        eng = PacketServeEngine(StatefulPipeline(_flow_stages(_spec())),
                                feature_dim=2, max_batch=8, telemetry=tel)
        eng.submit(X)
        eng.flush()
    snap = tel.snapshot()
    assert snap["serve_packets_total"]["values"][0]["value"] == 80


def test_mitigated_verdicts_are_counted():
    spec = _spec(n_slots=64)
    stages = _flow_stages(spec)
    rng = np.random.default_rng(7)
    n_in = stages[2].n_out
    w1 = rng.normal(size=(n_in, 6)).astype(np.float32)
    w2 = rng.normal(size=(6, 2)).astype(np.float32)
    mlp = stageir.FusedMLP([w1, w2], [np.zeros(6, np.float32),
                                      np.zeros(2, np.float32)])
    pipe = StatefulPipeline(
        stages + [mlp, stageir.Reduce("argmax"),
                  stageir.Mitigate(MitigationSpec(
                      n_slots=64, mode="drop", threshold=2))])
    eng = PacketServeEngine(pipe, feature_dim=2, max_batch=16)
    X = _flow_packets(np.random.default_rng(3), 400, flows=4)
    eng.submit(X)
    v = eng.flush()
    dropped = int((v == MITIGATED).sum())
    snap = eng.telemetry().snapshot()
    assert snap["serve_mitigated_packets_total"]["values"][0]["value"] \
        == dropped
    if dropped:   # engage event journaled at the flush-boundary scan
        assert "mitigation_engage" in eng.telemetry().journal.kinds()
        assert snap["flow_mit_marked"]["values"][0]["value"] > 0


def test_requested_pallas_fallback_is_journaled(monkeypatch):
    from repro.core import pallas_backend

    monkeypatch.setattr(pallas_backend, "pallas_available", lambda: False)
    eng = PacketServeEngine(StatefulPipeline(_flow_stages(_spec())),
                            feature_dim=2, max_batch=8, backend="pallas")
    evs = eng.telemetry().journal.events("backend_fallback")
    assert evs and evs[0]["requested"] == "pallas"
    assert evs[0]["actual"] == eng.backend


# ------------------------------------------- swap-concurrency property


@given(data=st.data())
@HSET
def test_counters_account_for_every_packet_across_swaps(data):
    """Satellite property: under arbitrary submit/flush/swap
    interleavings at depth > 1 — with the swap parked from a SEPARATE
    thread, racing the serving loop — the packet counter equals the
    packets submitted, batches equal lockstep+drain classifications, and
    the journal records exactly the installed swaps."""
    spec = _spec()
    eng = PacketServeEngine(StatefulPipeline(_flow_stages(spec)),
                            feature_dim=2, max_batch=8,
                            depth=data.draw(st.integers(2, 4)))
    eng.TELEMETRY_SEG_SAMPLE = 1
    rng = np.random.default_rng(data.draw(st.integers(0, 1000)))
    n_ops = data.draw(st.integers(1, 6))
    swap_at = data.draw(st.integers(0, n_ops - 1))
    total = 0
    for i in range(n_ops):
        if i == swap_at:
            t = threading.Thread(target=eng.swap, args=(
                StatefulPipeline(_flow_stages(spec)),))
            t.start()
            t.join()
        n = data.draw(st.integers(1, 40))
        eng.submit(_flow_packets(rng, n))
        total += n
        if data.draw(st.booleans()):
            eng.flush()
    assert len(eng.flush()) >= 0
    while eng.swap_pending:           # force the parked swap in
        eng.flush()

    snap = eng.telemetry().snapshot()
    one = {k: snap[k]["values"][0]["value"] for k in snap
           if snap[k]["kind"] == "counter"}
    assert one["serve_packets_total"] == total
    assert one["serve_packets_total"] + one["serve_pad_packets_total"] \
        == one["serve_batches_total"] * 8
    assert (one["flow_lockstep_batches_total"]
            + one["flow_drain_batches_total"]) \
        == one["serve_batches_total"]
    assert one["serve_swaps_total"] == eng.stats_.swaps == 1
    swaps = eng.telemetry().journal.events("hot_swap")
    assert len(swaps) == 1 and swaps[0]["pkt_offset"] <= total


# --------------------------------------- closed-loop replay event trail


def test_coordinated_ddos_replay_event_trail():
    """Acceptance: replaying coordinated_ddos against a drift-armed,
    mitigated engine journals drift, hot_swap and mitigation events with
    monotonic timestamps, and the Chrome trace validates structurally."""
    from repro.data import traffic

    spec = FlowStateSpec(n_slots=256, n_counters=1, n_ewma=1,
                         hist_sizes=(3,), ewma_alpha=0.5)
    fk = stageir.FlowKey((0, 3), spec.n_slots)
    ru = stageir.RegisterUpdate(
        spec, ewma_cols=(2,), hist_cols=(1,),
        hist_edges=(np.array([64.0, 512.0], np.float32),),
    )
    ws = stageir.WindowStats(spec, mode="all")

    def make_pipe():
        rng = np.random.default_rng(5)
        n_in = ws.n_out
        w1 = rng.normal(size=(n_in, 4)).astype(np.float32)
        w2 = rng.normal(size=(4, 2)).astype(np.float32)
        mlp = stageir.FusedMLP([w1, w2], [np.zeros(4, np.float32),
                                          np.zeros(2, np.float32)])
        return StatefulPipeline(
            [fk, ru, ws, mlp, stageir.Reduce("argmax"),
             stageir.Mitigate(MitigationSpec(
                 n_slots=256, mode="drop", threshold=2))])

    stream = traffic.make_stream("coordinated_ddos", n_packets=2000,
                                 seed=3)
    X = stream.packets
    eng = PacketServeEngine(make_pipe(),
                            feature_dim=len(traffic.COLUMNS),
                            max_batch=64, depth=2)
    snap0 = DriftSnapshot.from_packets(X[:256], cols=(1, 2), window=64)
    ctrl = HotSwapController(
        eng, DriftDetector(snap0, threshold=1e-6, patience=1),
        lambda windows: make_pipe(), buffer_windows=4)

    for i in range(0, len(X), 128):
        w = X[i:i + 128]
        ctrl.observe(w)
        eng.submit(w)
        eng.flush()
    assert ctrl.wait(30)
    eng.flush()                       # install the parked swap

    tel = eng.telemetry()
    kinds = tel.journal.kinds()
    assert {"drift", "retrain_start", "retrain_done", "hot_swap"} <= kinds
    assert "mitigation_engage" in kinds, (
        "coordinated_ddos replay must engage the action table")
    evs = tel.journal.events()
    ts = [e["t_s"] for e in evs]
    assert ts == sorted(ts) and [e["seq"] for e in evs] == list(
        range(len(evs)))
    # the trail is causally ordered: drift before retrain before swap
    first = {k: next(e["seq"] for e in evs if e["kind"] == k)
             for k in ("drift", "retrain_start", "hot_swap")}
    assert first["drift"] < first["retrain_start"] < first["hot_swap"]
    # Chrome trace validates structurally and serializes
    ct = tel.chrome_trace()
    assert {"warm_up", "dispatch", "batch", "swap_install"} <= {
        e["name"] for e in ct["traceEvents"]}
    json.dumps(ct)
