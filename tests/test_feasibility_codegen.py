"""Platform resource models + backend codegen (paper §3.3, Tables 2/5)."""

import numpy as np
import pytest

from repro.core import codegen, feasibility as feas, mlalgos
from repro.core.alchemy import Platforms


# ----------------------------------------------------------- Taurus model


def test_taurus_calibration_paper_scale():
    """A ~203-param DNN must land at the paper's Table-2 scale (24 CU/48 MU)."""
    model = feas.TaurusModel()
    # widths giving ~203 params: 7 -> 12 -> 8 -> 2 = 218 params
    est = model.estimate("dnn", {"widths": [7, 12, 8, 2]})
    o = est["options"][0]  # II=1
    assert 15 <= o["cu"] <= 45, o
    assert 25 <= o["mu"] <= 75, o
    assert o["throughput_pps"] == 1e9  # 1 GPkt/s at II=1 (paper line rate)


def test_taurus_ii_throughput_tradeoff():
    """Paper §3.2.2: more loop iterations (II) halve throughput, halve CUs."""
    model = feas.TaurusModel()
    est = model.estimate("dnn", {"widths": [30, 64, 64, 2]})
    o1, o2 = est["options"][0], est["options"][1]
    assert o2["cu"] < o1["cu"]
    assert o2["throughput_pps"] == o1["throughput_pps"] / 2


def test_taurus_platform_feasibility_boundary():
    p = Platforms.Taurus()
    p.constrain(performance={"throughput": 1, "latency": 500},
                resources={"rows": 16, "cols": 16})
    small = p.check("dnn", {"widths": [7, 16, 2]})
    assert small.feasible
    huge = p.check("dnn", {"widths": [64] + [128] * 10 + [2]})
    assert not huge.feasible
    assert any("CU" in r or "throughput" in r for r in huge.reasons)


def test_taurus_constraint_operator():
    p = Platforms.Taurus() < {
        "performance": {"throughput": 1, "latency": 500},
        "resources": {"rows": 8, "cols": 8},
    }
    assert p.model.rows == 8
    assert p.min_throughput_pps == 1e9
    assert p.max_latency_ns == 500


# --------------------------------------------------------------- MAT model


def test_mat_mapping_rules():
    """IIsy rules: kmeans = 1 MAT/cluster, svm = 1 MAT/feature,
    tree = 1 MAT/level, DNN = ~12 MATs/layer (N2Net)."""
    m = feas.MATModel()
    assert m.mats_for("kmeans", {"k": 5, "n_features": 7}) == 5
    assert m.mats_for("svm", {"n_features": 7, "n_classes": 3}) == 7
    assert m.mats_for("tree", {"nodes": [{}] * 31, "depth": 4}) == 4
    assert m.mats_for("dnn", {"widths": [7, 10, 10, 5, 2]}) == 48


def test_tofino_platform_rejects_dnn():
    p = Platforms.Tofino()
    p.constrain(resources={"tables": 12})
    assert "dnn" not in p.supported_algorithms()
    rep = p.check("kmeans", {"k": 5, "n_features": 7})
    assert rep.feasible
    rep = p.check("kmeans", {"k": 20, "n_features": 7})
    assert not rep.feasible


# -------------------------------------------------------------- FPGA / TPU


def test_fpga_estimate_scales_with_params():
    p = Platforms.FPGA()
    small = p.check("dnn", {"widths": [7, 10, 2]})
    big = p.check("dnn", {"widths": [30, 64, 64, 2]})
    assert small.feasible and big.feasible
    assert big.resources["luts"] > small.resources["luts"]


def test_tpu_platform_roofline_feasibility():
    p = Platforms.TPU()
    rep = p.check("dnn", {"widths": [7, 64, 2]})
    assert rep.feasible
    assert rep.throughput_pps > 1e7  # >10M pkt/s for a small fused MLP
    p2 = Platforms.TPU() < {"performance": {"throughput": 1000, "latency": 1}}
    rep2 = p2.check("dnn", {"widths": [7, 64, 2]})
    assert not rep2.feasible  # 1000 GPkt/s is beyond the roofline


def test_report_merge_semantics():
    a = feas.FeasibilityReport(True, [], {"cu": 10, "mu": 5}, 10.0, 1e9)
    b = feas.FeasibilityReport(True, [], {"cu": 7, "mu": 3}, 5.0, 5e8)
    m = a.merge(b)
    assert m.resources == {"cu": 17, "mu": 8}
    assert m.latency_ns == 15.0
    assert m.throughput_pps == 5e8  # min (paper §3.2.1 consistency rule)


# ------------------------------------------------------------------ codegen


@pytest.fixture(scope="module")
def trained_models(ad_data):
    dnn = mlalgos.train_dnn(ad_data, hidden=[16, 8], epochs=4, seed=0)
    svm = mlalgos.train_svm(ad_data, c_reg=1.0, epochs=6, seed=0)
    km = mlalgos.train_kmeans(ad_data, k=4, seed=0)
    return {"dnn": dnn, "svm": svm, "kmeans": km}


def _report():
    return feas.FeasibilityReport(True, [], {"cu": 1, "mu": 1}, 1.0, 1e9)


@pytest.mark.parametrize("algo", ["dnn", "svm", "kmeans"])
def test_taurus_codegen_exact(algo, trained_models, ad_data):
    tm = trained_models[algo]
    pipe = codegen.taurus_codegen(f"t_{algo}", tm, _report())
    assert pipe.verify(ad_data.test_x, max_mismatch_frac=0.0) == 0.0
    assert "Accel {" in pipe.source
    assert "Reduce" in pipe.source or "argm" in pipe.source


@pytest.mark.parametrize("algo", ["svm", "kmeans"])
def test_mat_codegen_quantization_bounded(algo, trained_models, ad_data):
    tm = trained_models[algo]
    pipe = codegen.mat_codegen(f"m_{algo}", tm, _report(), ad_data.train_x)
    frac = pipe.verify(ad_data.test_x, max_mismatch_frac=0.03)
    assert frac <= 0.03  # 512-bin quantized LUTs: <=3% label flips
    assert "table score_f0" in pipe.source
    assert "apply {" in pipe.source


def test_dnn_codegen_uses_fused_kernel_math(trained_models, ad_data):
    """The generated Taurus pipeline must execute the same math as the
    trained model (mlalgos.mlp_forward) — via the fused_mlp kernel."""
    tm = trained_models["dnn"]
    pipe = codegen.taurus_codegen("ad", tm, _report())
    X = ad_data.test_x[:256]
    np.testing.assert_array_equal(pipe(X), tm.predict(X))
