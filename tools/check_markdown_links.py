#!/usr/bin/env python3
"""Check that every intra-repo markdown link resolves.

Scans all tracked ``*.md`` files for inline links ``[text](target)`` and
reference-style definitions ``[label]: target``, skipping external
targets (``http(s)://``, ``mailto:``) and anything inside fenced code
blocks, and verifies that

  * relative file targets exist on disk, and
  * ``#anchor`` fragments (same-file or cross-file) match a heading in the
    target document under GitHub's slugification rules — including the
    ``-1``/``-2`` suffixes GitHub appends to repeated headings and
    explicit ``<a id="...">``/``<a name="...">`` HTML anchors.

Exit code 0 when every link resolves; 1 with a per-link report otherwise.
Run from anywhere:  ``python tools/check_markdown_links.py [root]``.
This is what the CI docs job runs; tests/test_docs.py runs it in-process.
"""

from __future__ import annotations

import os
import re
import sys

SKIP_DIRS = {".git", "__pycache__", "results", ".claude"}
# reference scrapbooks excerpted from external repos/papers: their links
# point at documents that were never part of this repository
SKIP_FILES = {"SNIPPETS.md", "PAPERS.md"}
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# reference-style definitions: [label]: target  (column 0, possibly
# indented up to 3 spaces per CommonMark)
REF_DEF_RE = re.compile(r"^ {0,3}\[[^\]]+\]:\s*(\S+)", re.MULTILINE)
FENCE_RE = re.compile(r"```.*?```", re.DOTALL)
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
# explicit HTML anchors: <a id="..."> / <a name="...">
HTML_ANCHOR_RE = re.compile(
    r"""<a\s+(?:id|name)\s*=\s*["']([^"']+)["']""", re.IGNORECASE)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces->hyphens."""
    heading = re.sub(r"`([^`]*)`", r"\1", heading)          # inline code
    heading = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", heading)  # links
    heading = heading.strip().lower()
    heading = re.sub(r"[^\w\- ]", "", heading)
    return heading.replace(" ", "-")


def md_files(root: str) -> list[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        out += [os.path.join(dirpath, f) for f in filenames
                if f.endswith(".md") and f not in SKIP_FILES]
    return sorted(out)


def anchors_of(path: str) -> set[str]:
    """Every anchor the document exposes: heading slugs — with GitHub's
    ``-1``/``-2`` dedup suffixes for repeated headings — plus explicit
    ``<a id=...>``/``<a name=...>`` HTML anchors."""
    text = FENCE_RE.sub("", open(path, encoding="utf-8").read())
    anchors: set[str] = set()
    seen: dict[str, int] = {}
    for h in HEADING_RE.findall(text):
        slug = github_slug(h)
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        anchors.add(slug if n == 0 else f"{slug}-{n}")
    anchors.update(HTML_ANCHOR_RE.findall(text))
    return anchors


def link_targets(text: str) -> list[str]:
    """Inline link targets + reference-style definition targets."""
    return LINK_RE.findall(text) + REF_DEF_RE.findall(text)


def check_file(path: str, root: str) -> list[str]:
    errors = []
    text = FENCE_RE.sub("", open(path, encoding="utf-8").read())
    rel = os.path.relpath(path, root)
    for target in link_targets(text):
        if re.match(r"^[a-z][a-z0-9+.-]*:", target):    # external scheme
            continue
        file_part, _, anchor = target.partition("#")
        if file_part:
            dest = os.path.normpath(
                os.path.join(os.path.dirname(path), file_part)
            )
            if not os.path.exists(dest):
                errors.append(f"{rel}: broken link -> {target}")
                continue
        else:
            dest = path
        if anchor and dest.endswith(".md"):
            if anchor not in anchors_of(dest) \
                    and github_slug(anchor) not in anchors_of(dest):
                errors.append(f"{rel}: missing anchor -> {target}")
    return errors


def check_tree(root: str) -> list[str]:
    errors = []
    for path in md_files(root):
        errors += check_file(path, root)
    return errors


def main(argv: list[str]) -> int:
    root = os.path.abspath(argv[1]) if len(argv) > 1 else os.path.abspath(
        os.path.join(os.path.dirname(__file__), os.pardir)
    )
    errors = check_tree(root)
    for e in errors:
        print(e, file=sys.stderr)
    n = len(md_files(root))
    print(f"checked {n} markdown files: "
          f"{'OK' if not errors else f'{len(errors)} broken link(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
