"""Paper Figure 4: regret plot (best F1 so far per BO iteration) for the
anomaly-detection DNN on the MapReduce grid."""

from __future__ import annotations

from homunculus.alchemy import DataLoader, Model, Platforms
from repro.core.dse import search_model
from repro.data import netdata

from benchmarks.common import Timer, save_result


def _ascii_plot(curve, width=60, height=12) -> str:
    import math

    vals = [v if math.isfinite(v) else 0.0 for v in curve]
    lo, hi = min(vals), max(vals)
    span = (hi - lo) or 1.0
    rows = []
    for r in range(height, -1, -1):
        thr = lo + span * r / height
        line = "".join(
            "#" if vals[int(i * (len(vals) - 1) / (width - 1))] >= thr else " "
            for i in range(width)
        )
        rows.append(f"{thr:7.3f} |{line}")
    rows.append(" " * 8 + "+" + "-" * width)
    rows.append(" " * 9 + f"iteration 0..{len(curve) - 1}")
    return "\n".join(rows)


def main(budget: int = 24) -> dict:
    @DataLoader
    def ad_loader():
        return netdata.make_ad_dataset(features=7, n_train=4096, n_test=2048)

    model = Model({
        "optimization_metric": ["f1"], "algorithm": ["dnn"],
        "name": "anomaly_detection", "data_loader": ad_loader,
    })
    p = Platforms.Taurus()
    p.constrain(performance={"throughput": 1, "latency": 500},
                resources={"rows": 16, "cols": 16})

    with Timer() as t:
        res = search_model(p, model, budget=budget, n_init=8, seed=0)

    print("\n== Figure 4: regret (best F1 so far) — AD DNN on MapReduce grid ==")
    print(_ascii_plot(res.regret))
    print(f"final best F1 = {res.value:.4f}  ({len(res.history)} iterations)")
    assert all(b >= a for a, b in zip(res.regret, res.regret[1:]))
    payload = {
        "regret": res.regret,
        "per_iteration_f1": [
            o.value if o.feasible else None for o in res.history
        ],
        "best_f1": res.value,
        "wall_s": round(t.wall_s, 1),
    }
    save_result("fig4_regret", payload)
    return payload


if __name__ == "__main__":
    main()
