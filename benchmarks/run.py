"""Benchmark harness: one entry per paper table/figure + system benches.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table2 fig7  # a subset

After the selected benches run, the per-engine serving stats recorded by
``dag_throughput`` / ``flow_throughput`` are consolidated into
``benchmarks/results/BENCH_serve.json`` — the machine-readable perf
trajectory (pkt/s + p50/p95/p99 latency per engine x backend) future PRs
diff throughput against.  ``ShardedPacketServeEngine`` rows are measured
in forced-multi-device subprocesses (``common.run_sharded_probe``), so
their ``shards`` field records the actual device count of the run — one
stateless (ad>tc) and one stateful (flow-ddos, fused launch) row.
"""

from __future__ import annotations

import json
import os
import sys
import time
import traceback

from benchmarks import (
    attack_defense,
    dag_throughput,
    dryrun_roofline,
    dse_throughput,
    fig4_regret,
    flow_throughput,
    fig6_reaction_time,
    fig7_kmeans_mats,
    hot_swap,
    kernel_roofline,
    table2_f1,
    table3_chaining,
    table4_fusion,
    table5_resources,
    telemetry_overhead,
)

BENCHES = {
    "table2": ("Table 2: baselines vs generated F1/resources", table2_f1.main),
    "table3": ("Table 3: chaining strategies", table3_chaining.main),
    "table4": ("Table 4: model fusion", table4_fusion.main),
    "table5": ("Table 5: FPGA resources", table5_resources.main),
    "fig4": ("Figure 4: BO regret", fig4_regret.main),
    "fig6": ("Figure 6: reaction time", fig6_reaction_time.main),
    "fig7": ("Figure 7: KMeans vs MATs", fig7_kmeans_mats.main),
    "dag": ("whole-DAG JIT vs interpreted chaining pkt/s",
            dag_throughput.main),
    "dse": ("sequential vs batched DSE candidates/sec",
            dse_throughput.main),
    "flow": ("stateful flow pipeline: interpreter vs fused launch pkt/s",
             flow_throughput.main),
    "attack": ("closed-loop attack/defense replay with SLO gates",
               attack_defense.main),
    "swap": ("hot-swap latency + post-drift F1 recovery", hot_swap.main),
    "kernel": ("fused_mlp kernel roofline + stateful step",
               kernel_roofline.main),
    "telemetry": ("telemetry plane overhead: pkt/s on vs off",
                  telemetry_overhead.main),
    "dryrun": ("dry-run roofline summary", dryrun_roofline.main),
}


# benches whose saved results carry "serve_stats" entries
_SERVE_SOURCES = ("dag_throughput", "flow_throughput", "hot_swap",
                  "attack_defense", "telemetry_overhead")


def write_bench_serve() -> str | None:
    """Consolidate serve_stats from the source benches' saved results into
    benchmarks/results/BENCH_serve.json; returns the path (None when no
    source results exist yet)."""
    from benchmarks.common import RESULTS_DIR, save_result

    entries = []
    for name in _SERVE_SOURCES:
        path = os.path.join(RESULTS_DIR, f"{name}.json")
        if not os.path.exists(path):
            continue
        with open(path) as f:
            payload = json.load(f)
        for e in payload.get("serve_stats", []):
            entries.append({"bench": name, **e})
    if not entries:
        return None
    return save_result("BENCH_serve", {
        "description": "pkt/s + latency percentiles per serving engine x "
                       "execution backend (consolidated perf trajectory)",
        "entries": entries,
    })


def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    summary = []
    for name in names:
        desc, fn = BENCHES[name]
        print(f"\n{'=' * 72}\n[{name}] {desc}\n{'=' * 72}", flush=True)
        t0 = time.perf_counter()
        try:
            fn()
            status = "ok"
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            status = f"FAIL {type(e).__name__}: {e}"
        summary.append((name, status, time.perf_counter() - t0))

    # ALWAYS consolidate, even when benches failed their gates: every
    # bench saves its artifact BEFORE asserting (the PR-6 convention), so
    # the trajectory refreshes from whatever measurements exist
    try:
        serve_path = write_bench_serve()
    except Exception:  # noqa: BLE001 — the trajectory is best-effort
        traceback.print_exc()
        serve_path = None
    if serve_path:
        print(f"\nconsolidated serving stats -> {serve_path}")

    print(f"\n{'=' * 72}\nbenchmark summary\n{'=' * 72}")
    print("name,status,wall_s")
    failed = 0
    for name, status, wall in summary:
        print(f"{name},{status},{wall:.1f}")
        failed += status != "ok"
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
