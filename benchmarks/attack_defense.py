"""Closed-loop attack/defense replay harness with SLO gates.

For each flood scenario: train a detector on one seeded stream, attach an
in-pipeline ``Mitigate`` stage (per-flow drop registers), replay a
DIFFERENT seed of the same scenario through ``PacketServeEngine`` on both
execution engines, and gate on what the data plane ENFORCES:

  * median packets-to-first-DROP per attack flow (detection reaction +
    mitigation lag) <= the scenario's SLO;
  * ZERO attack packets leaked after a flow's first drop (drop mode is
    leak-free by construction — this gate catches any regression in the
    action-table carry across batches, overlap depth, or engines);
  * detection rate >= 0.9, benign collateral damage bounded.

The replay is fully deterministic (seeded streams, seeded training, CPU
math), so these are structural gates, not timing gates — they run hard
in CI.  A forced-4-device subprocess serves the same mitigated pipeline
through ``ShardedPacketServeEngine`` for the shards > 1 row, and a
rate-limit run hot-swaps the detector MID-mitigation to pin the
swap-while-limited contract.  All rows consolidate into BENCH_serve.json
via benchmarks.run.

  PYTHONPATH=src python -m benchmarks.attack_defense
"""

from __future__ import annotations

import os
import textwrap

import numpy as np

from repro.core import codegen, mlalgos, stageir
from repro.data import traffic
from repro.flowstate import MITIGATED, MitigationSpec, StatefulPipeline
from repro.serve.packet_engine import PacketServeEngine
from repro.telemetry import Telemetry

from benchmarks.common import (
    RESULTS_DIR,
    render_table,
    run_sharded_probe,
    save_result,
)

# the operator event journal of the replay (drift/swap/mitigation/SLO
# events, JSON lines) — CI uploads this file as a build artifact
JOURNAL_PATH = os.path.join(RESULTS_DIR, "attack_defense_journal.jsonl")

N_PACKETS = 12_000
N_SLOTS = 2048          # detection table
MIT_SLOTS = 4096        # action table (generous: collisions would evict
                        # marked flows and show up as leaked packets)
THRESHOLD = 8
BATCH = 512
TRAIN_SEED, REPLAY_SEED = 0, 1

SCENARIOS = ("syn_flood", "udp_flood", "coordinated_ddos")

# median packets until the data plane STOPS an attack flow (detection
# reaction + mitigation lag), per scenario
SLO_REACTION_PKTS = {"syn_flood": 64, "udp_flood": 64,
                     "coordinated_ddos": 96}
SLO_DETECTION_RATE = 0.9
SLO_BENIGN_MITIGATED = 0.25


def build_pipeline(scenario: str, *, mode: str = "drop",
                   keep_every: int = 4):
    """Train the scenario's detector and cap it with a Mitigate stage."""
    train = traffic.make_stream(scenario, n_packets=N_PACKETS,
                                seed=TRAIN_SEED)
    stages, names = traffic.flow_feature_stages(n_slots=N_SLOTS)
    ds, mu, sd = traffic.stream_feature_dataset(train, stages, names,
                                                sample_every=4)
    dnn = mlalgos.train_dnn(ds, hidden=[16, 8], epochs=3, seed=0)
    suffix = traffic.fold_input_standardization(
        codegen.taurus_stages(dnn), mu, sd)
    mit = stageir.Mitigate(MitigationSpec(
        n_slots=MIT_SLOTS, mode=mode, threshold=THRESHOLD,
        keep_every=keep_every))
    return list(stages) + suffix + [mit]


def serve_once(pipe, stream, *, depth: int = 2, telemetry=False):
    eng = PacketServeEngine(pipe, feature_dim=len(traffic.COLUMNS),
                            max_batch=BATCH, depth=depth,
                            telemetry=telemetry)
    v = np.concatenate(list(eng.serve_stream(stream.chunks(BATCH))))
    return v, eng


_SHARDED_SCRIPT = textwrap.dedent(f"""
    import json
    import jax
    import numpy as np
    from benchmarks.attack_defense import (BATCH, N_PACKETS, REPLAY_SEED,
                                           build_pipeline)
    from repro.data import traffic
    from repro.flowstate import MITIGATED, StatefulPipeline
    from repro.serve import ShardedPacketServeEngine

    assert len(jax.devices()) == 4, jax.devices()
    pipe = StatefulPipeline(build_pipeline("syn_flood"), backend="pallas")
    assert pipe.backend == "pallas-fused-flow", pipe.backend
    stream = traffic.make_stream("syn_flood", n_packets=N_PACKETS,
                                 seed=REPLAY_SEED)
    eng = ShardedPacketServeEngine(pipe, feature_dim=len(traffic.COLUMNS),
                                   max_batch=BATCH)
    assert eng.sharded and eng.n_shards == 4, (eng.sharded, eng.n_shards)
    for _ in range(2):
        dropped = 0
        for v in eng.serve_stream(stream.chunks(BATCH)):
            dropped += int((v == MITIGATED).sum())
    assert dropped > 0, "sharded replay mitigated nothing"
    assert int(eng.state.mitigated_flows) > 0
    print("SHARDED-STATS " + json.dumps(eng.stats()))
""")


def _stat_row(stats: dict, pipeline: str, engine: str) -> dict:
    return {
        "engine": engine,
        "pipeline": pipeline,
        "backend": stats["backend"],
        "depth": stats["depth"],
        "shards": stats["shards"],
        "pkt_per_s": stats["pkt_per_s"],
        "lat_p50_ms": stats["lat_p50_ms"],
        "lat_p95_ms": stats["lat_p95_ms"],
        "lat_p99_ms": stats["lat_p99_ms"],
    }


def swap_under_rate_limit() -> dict:
    """Hot-swap the detector while flows are actively rate-limited; the
    action table must carry bit-identically (same verdict stream as the
    unswapped run) and the swap must count exactly once."""
    stages = build_pipeline("syn_flood", mode="rate_limit")
    stream = traffic.make_stream("syn_flood", n_packets=N_PACKETS,
                                 seed=REPLAY_SEED)
    chunks = list(stream.chunks(BATCH))

    pipe = StatefulPipeline(stages, backend="pallas")
    assert pipe.backend == "pallas-fused-flow", (
        f"rate-limit pipeline outside the fused envelope: {pipe.backend!r} "
        f"(reason: {pipe.fallback_reason})")
    ref, _ = serve_once(pipe, stream)

    eng = PacketServeEngine(StatefulPipeline(stages, backend="pallas"),
                            feature_dim=len(traffic.COLUMNS),
                            max_batch=BATCH, depth=2)
    got = []
    for i, c in enumerate(chunks):
        if i == len(chunks) // 2:
            assert int(eng.state.mitigated_flows) > 0, \
                "swap must land while flows are being rate-limited"
            eng.swap(StatefulPipeline(stages, backend="pallas"))
        eng.submit(c)
        got.append(eng.flush())
    v = np.concatenate(got)
    np.testing.assert_array_equal(
        v, ref, err_msg="hot swap perturbed the mitigation stream")
    assert eng.stats()["swaps"] == 1
    return {
        "dropped_pkts": int((v == MITIGATED).sum()),
        "mitigated_flows": int(eng.state.mitigated_flows),
        "swap_lat_ms": eng.stats()["swap_lat_ms"],
    }


def main() -> dict:
    # ONE shared telemetry plane for the whole replay: every scenario's
    # pallas engine reports into it, and its journal (mitigation
    # engagements, SLO-gate outcomes) lands in the JSON-lines artifact
    os.makedirs(RESULTS_DIR, exist_ok=True)
    if os.path.exists(JOURNAL_PATH):
        os.remove(JOURNAL_PATH)
    tel = Telemetry(journal_path=JOURNAL_PATH)

    rows, serve_stats, reports, gates = [], [], {}, []
    for scenario in SCENARIOS:
        stages = build_pipeline(scenario)
        stream = traffic.make_stream(scenario, n_packets=N_PACKETS,
                                     seed=REPLAY_SEED)
        verdicts, engines = {}, {}
        for backend in ("interpret", "pallas"):
            pipe = StatefulPipeline(stages, backend=backend)
            if backend == "pallas":
                # the action table folds into the fused launch: the whole
                # mitigated chain must serve as ONE kernel
                assert pipe.backend == "pallas-fused-flow", (
                    f"{scenario}: expected the fused launch, got "
                    f"{pipe.backend!r} (reason: {pipe.fallback_reason})")
            verdicts[backend], engines[backend] = serve_once(
                pipe, stream,
                telemetry=tel if backend == "pallas" else False)
        np.testing.assert_array_equal(
            verdicts["interpret"], verdicts["pallas"],
            err_msg=f"{scenario}: engines diverged under mitigation")
        for f in ("mit_keys", "mit_regs"):
            np.testing.assert_array_equal(
                np.asarray(getattr(engines["interpret"].state, f)),
                np.asarray(getattr(engines["pallas"].state, f)),
                err_msg=f"{scenario}: final {f} diverged")

        react = traffic.reaction_report(stream, verdicts["pallas"])
        reports[scenario] = react
        stop_median = (react["reaction_pkts_median"]
                       + react["mitigation_lag_median"])
        rows.append({
            "scenario": scenario,
            "attack_flows": react["attack_flows"],
            "detect_rate": round(react["detection_rate"], 3),
            "stop_median_pkts": stop_median,
            "slo_pkts": SLO_REACTION_PKTS[scenario],
            "leaked": react["leaked_pkts_total"],
            "benign_hit": round(react["benign_mitigated_flow_rate"], 3),
        })
        serve_stats.append(_stat_row(engines["pallas"].stats(),
                                     f"mitigate-{scenario}",
                                     "PacketServeEngine"))
        gates.append((scenario, react, stop_median))

    print("\n== closed-loop replay: packets until the data plane stops an "
          "attack flow ==")
    print(render_table(rows, ["scenario", "attack_flows", "detect_rate",
                              "stop_median_pkts", "slo_pkts", "leaked",
                              "benign_hit"]))

    swap = swap_under_rate_limit()
    print(f"\nswap under rate-limit: {swap}")

    sharded = run_sharded_probe(_SHARDED_SCRIPT)
    assert sharded["shards"] > 1, \
        f"sharded probe degraded to {sharded['shards']} shard"
    serve_stats.append(_stat_row(sharded, "mitigate-syn_flood",
                                 "ShardedPacketServeEngine"))

    print("\n== serving-engine stats (BENCH_serve entries) ==")
    print(render_table(
        serve_stats,
        ["engine", "pipeline", "backend", "depth", "shards", "pkt_per_s",
         "lat_p50_ms", "lat_p95_ms", "lat_p99_ms"]))

    # journal every SLO outcome FIRST — a violated gate must show up in
    # the uploaded artifact, not vanish with the raised assert
    outcomes = []
    for scenario, react, stop_median in gates:
        slo = SLO_REACTION_PKTS[scenario]
        checks = {
            "detection_rate": bool(react["detection_rate"]
                                   >= SLO_DETECTION_RATE),
            "stop_median_pkts": bool(stop_median <= slo),
            "leaked_pkts": react["leaked_pkts_total"] == 0,
            "benign_collateral": bool(react["benign_mitigated_flow_rate"]
                                      <= SLO_BENIGN_MITIGATED),
        }
        tel.journal.emit(
            "slo_gate", scenario=scenario, ok=all(checks.values()),
            checks=checks,
            detection_rate=round(react["detection_rate"], 4),
            stop_median_pkts=stop_median, slo_pkts=slo,
            leaked_pkts=react["leaked_pkts_total"],
            benign_rate=round(react["benign_mitigated_flow_rate"], 4))
        outcomes.append((scenario, react, stop_median, slo, checks))

    payload = {
        "n_packets": N_PACKETS,
        "mit_slots": MIT_SLOTS,
        "threshold": THRESHOLD,
        "slo_reaction_pkts": SLO_REACTION_PKTS,
        "reports": reports,
        "swap_under_rate_limit": swap,
        "serve_stats": serve_stats,
        "journal_path": JOURNAL_PATH,
        "journal_events": len(tel.journal.events()),
    }
    save_result("attack_defense", payload)
    tel.close()
    print(f"\noperator event journal -> {JOURNAL_PATH} "
          f"({payload['journal_events']} events)")

    # SLO gates LAST, after the artifact records the measured numbers —
    # a violated SLO must fail the gate, not erase the trajectory entry
    for scenario, react, stop_median, slo, checks in outcomes:
        assert checks["detection_rate"], (
            f"{scenario}: detection rate {react['detection_rate']:.3f} "
            f"below {SLO_DETECTION_RATE}")
        assert checks["stop_median_pkts"], (
            f"{scenario}: median packets-to-stop {stop_median} exceeds "
            f"the {slo}-packet SLO")
        assert checks["leaked_pkts"], (
            f"{scenario}: {react['leaked_pkts_total']} attack packets "
            f"leaked past installed drop entries")
        assert checks["benign_collateral"], (
            f"{scenario}: benign collateral "
            f"{react['benign_mitigated_flow_rate']:.3f} above "
            f"{SLO_BENIGN_MITIGATED}")
    return payload


if __name__ == "__main__":
    main()
