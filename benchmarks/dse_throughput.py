"""DSE throughput: sequential vs population-parallel candidate training.

The slowest stage of the whole compiler is candidate evaluation (train ->
metric x feasibility).  This bench measures candidates/sec on the paper's
Table-2 anomaly-detection app two ways:

  * sequential — the pre-batching engine: one ``mlalgos.train`` call per
    BO proposal (one jit compile + one dispatch per distinct topology);
  * batched    — ``mlalgos.train_batch``: proposals bucketed by padded
    layer topology, ONE vmapped+jitted Adam run per bucket, feasibility
    for the whole population via ``platform.check_batch``.

Both paths train the *same* population from the same seed; predictions are
asserted equal lane-for-lane.  ``cold`` includes jit compilation (what a
fresh ``generate()`` pays), ``warm`` is steady-state.  A second section
runs a tiny end-to-end ``search_model`` both ways and asserts the batched
racer returns the same best config as the sequential reference.

  PYTHONPATH=src python -m benchmarks.dse_throughput
"""

from __future__ import annotations

import numpy as np

from repro.core import dse, mlalgos
from repro.core.alchemy import DataLoader, Model, Platforms
from repro.core.designspace import algorithm_space
from repro.core.traincache import CandidateCache
from repro.data import netdata

from benchmarks.common import Timer, render_table, save_result

POPULATION = 16
SEED = 0


def _population(space, rng, n: int) -> list[dict]:
    """n DNN proposals in the bucketed-NAS shape a BO round produces:
    topology + lr vary, minibatch/epochs fixed (one racer, one round)."""
    cfgs = []
    for _ in range(n):
        cfg = space.sample(rng)
        cfg["n_layers"] = int(rng.integers(1, 4))
        cfg["batch"], cfg["epochs"] = 256, 8
        cfgs.append(cfg)
    return cfgs


def _time_both(data, cfgs):
    def seq():
        return [mlalgos.train("dnn", data, c, seed=SEED) for c in cfgs]

    def bat():
        return mlalgos.train_batch("dnn", data, cfgs, seed=SEED)

    rows, models = [], {}
    for name, fn in (("sequential", seq), ("batched", bat)):
        with Timer() as cold:
            models[name] = fn()
        with Timer() as warm:
            fn()
        rows.append({
            "path": name,
            "cold_s": round(cold.wall_s, 2),
            "warm_s": round(warm.wall_s, 2),
            "cold_cps": round(len(cfgs) / cold.wall_s, 2),
            "warm_cps": round(len(cfgs) / warm.wall_s, 2),
        })
    return rows, models


def main() -> dict:
    data = netdata.make_ad_dataset(features=7, n_train=2048, n_test=1024)
    space = algorithm_space("dnn", n_features=data.num_features,
                            num_classes=data.num_classes, max_neurons=32)
    cfgs = _population(space, np.random.default_rng(SEED), POPULATION)

    rows, models = _time_both(data, cfgs)
    for ts, tb in zip(models["sequential"], models["batched"]):
        # padded vmap lanes match sequential training up to float
        # reduction order; allow the odd near-tie argmax flip
        mismatch = np.mean(ts.predict(data.test_x)
                           != tb.predict(data.test_x))
        assert mismatch <= 0.01, \
            f"batched candidate diverged from sequential training " \
            f"({mismatch:.2%} label flips)"

    # batched feasibility over the same population
    platform = Platforms.Taurus()
    topologies = [t.topology for t in models["batched"]]
    with Timer() as t_loop:
        loop_reports = [platform.check(t.algorithm, topo)
                        for t, topo in zip(models["batched"], topologies)]
    with Timer() as t_batch:
        batch_reports = platform.check_batch("dnn", topologies)
    assert [r.resources for r in loop_reports] == \
        [r.resources for r in batch_reports]

    speedup_cold = rows[0]["cold_cps"] and rows[1]["cold_cps"] / rows[0]["cold_cps"]
    speedup_warm = rows[1]["warm_cps"] / rows[0]["warm_cps"]
    print(f"\n== DSE candidate training: {POPULATION} DNN candidates "
          f"(AD, Table 2) ==")
    print(render_table(rows, ["path", "cold_s", "warm_s", "cold_cps",
                              "warm_cps"]))
    print(f"speedup (candidates/sec): cold {speedup_cold:.2f}x, "
          f"warm {speedup_warm:.2f}x")
    print(f"check_batch vs check-loop: {t_loop.wall_s / t_batch.wall_s:.1f}x "
          f"on feasibility accounting")

    # tiny end-to-end race: batched must return the sequential best config
    @DataLoader
    def loader():
        return netdata.make_ad_dataset(features=7, n_train=1024, n_test=512)

    def _search(mode):
        m = Model({"optimization_metric": ["f1"], "algorithm": ["dnn"],
                   "name": "ad", "data_loader": loader})
        p = Platforms.Taurus()
        p.constrain(performance={"throughput": 1, "latency": 500},
                    resources={"rows": 16, "cols": 16})
        with Timer() as t:
            r = dse.search_model(p, m, budget=10, n_init=4, seed=1,
                                 eval_mode=mode, cache=CandidateCache())
        return r, t.wall_s

    rb, wall_b = _search("batched")
    rs, wall_s = _search("sequential")
    assert rb.algorithm == rs.algorithm and \
        rb.trained.config == rs.trained.config, \
        "batched racer diverged from the sequential reference"
    print(f"\nsearch_model(budget=10): batched {wall_b:.1f}s vs "
          f"sequential {wall_s:.1f}s — same best config "
          f"({rb.algorithm}, F1 {rb.value:.4f}); at this toy budget both "
          f"are compile-dominated — the cold candidates/sec column above "
          f"(16 per-topology compiles collapsing into a few bucket "
          f"compiles) is what a fresh generate() pays")

    payload = {
        "population": POPULATION,
        "rows": rows,
        "speedup_cold": round(speedup_cold, 2),
        "speedup_warm": round(speedup_warm, 2),
        "speedup": round(max(speedup_cold, speedup_warm), 2),
        "search_same_best_config": True,
        "search_wall_s": {"batched": round(wall_b, 1),
                          "sequential": round(wall_s, 1)},
    }
    assert payload["speedup"] >= 3.0, (
        f"batched DSE below the 3x target: {payload}"
    )
    save_result("dse_throughput", payload)
    return payload


if __name__ == "__main__":
    main()
