"""Paper Figure 6 + §5.1.1: botnet vs benign histograms diverge early; F1 on
*partial* per-packet flowmarkers approaches flow-level F1 within tens of
packets — the reaction-time argument (3600 s -> per-packet)."""

from __future__ import annotations

import numpy as np

from repro.core import mlalgos
from repro.data import netdata

from benchmarks.common import Timer, render_table, save_result


def main() -> dict:
    with Timer() as t:
        data, test_flows = netdata.make_bd_dataset(n_flows=3000)
        model = mlalgos.train_dnn(data, hidden=[32, 16], epochs=12, seed=0)

        f1_full = mlalgos.f1_score(data.test_y, model.predict(data.test_x))
        checkpoints = (2, 5, 10, 20, 40, 80)
        partial = netdata.bd_partial_eval_set(test_flows, checkpoints)
        rows = []
        for k in checkpoints:
            X, y = partial[k]
            f1 = mlalgos.f1_score(y, model.predict(X))
            rows.append({
                "packets_seen": k,
                "f1_partial": round(f1, 4),
                "frac_of_flow_level": round(f1 / f1_full, 3),
            })

        # class-mean histogram divergence (Fig. 6's visual, as L1 distance)
        m = netdata.mean_histograms(test_flows)
        l1 = float(np.abs(m["botnet"] - m["benign"]).sum())

    print("\n== Fig 6 / §5.1.1: per-packet partial-flowmarker F1 ==")
    print(render_table(rows, list(rows[0])))
    print(f"flow-level F1 = {f1_full:.4f}   class-mean histogram L1 = {l1:.3f}")
    print("reaction time: flow-level waits up to 3600 s; per-packet reacts "
          "at packet arrival (~ns at line rate)")
    payload = {
        "flow_level_f1": f1_full, "partial": rows,
        "hist_l1": l1, "wall_s": round(t.wall_s, 1),
    }
    save_result("fig6_reaction_time", payload)
    return payload


if __name__ == "__main__":
    main()
