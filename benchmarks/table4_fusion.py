"""Paper Table 4: model fusion — two models on split halves of the AD
dataset vs one fused shared-trunk model: ~half the resources, same F1."""

from __future__ import annotations

from repro.core import fusion, mlalgos
from repro.core.feasibility import TaurusModel
from repro.data import netdata

from benchmarks.common import Timer, render_table, save_result


def main() -> dict:
    with Timer() as t:
        d = netdata.make_ad_dataset(features=7, n_train=8192, n_test=4096)
        part1, part2 = d.split_half()
        tm = TaurusModel()
        hidden = [24, 16]

        rows = []
        f1s = {}
        for name, part in (("AD: Part 1", part1), ("AD: Part 2", part2)):
            m = mlalgos.train_dnn(part, hidden=hidden, epochs=10, seed=0)
            est = tm.estimate("dnn", m.topology)["options"][0]
            f1 = mlalgos.f1_score(part.test_y, m.predict(part.test_x))
            f1s[name] = round(f1, 4)
            rows.append({"model": name, "pcu": est["cu"], "pmu": est["mu"],
                         "f1": round(f1, 4)})

        assert fusion.should_fuse(part1, part2)
        fused = fusion.fuse([part1, part2], hidden=hidden, epochs=10)
        est = tm.estimate("dnn", fused.fused_topology())["options"][0]
        rows.append({
            "model": "AD: Fused", "pcu": est["cu"], "pmu": est["mu"],
            "f1": f"{fused.f1(0):.4f}/{fused.f1(1):.4f}",
        })

    print("\n== Table 4: fused resource usage ==")
    print(render_table(rows, ["model", "pcu", "pmu", "f1"]))
    sum_cu = rows[0]["pcu"] + rows[1]["pcu"]
    print(f"fused CU {rows[2]['pcu']} vs separate sum {sum_cu} "
          f"({rows[2]['pcu'] / sum_cu:.2f}x) — ~half, as Table 4")
    assert rows[2]["pcu"] < 0.7 * sum_cu
    payload = {"rows": rows, "wall_s": round(t.wall_s, 1)}
    save_result("table4_fusion", payload)
    return payload


if __name__ == "__main__":
    main()
