"""Hot-swap benchmark: swap latency + post-drift F1 recovery rows.

Builds the ``concept_drift`` scenario's two models deterministically — a
phase-A classifier (the signature before the drift) and its replacement
trained on drifted traffic — then serves the drifting stream through
``PacketServeEngine`` and ``ShardedPacketServeEngine`` with an atomic
``swap`` injected at the detection point.  No background thread here:
the benchmark measures the SWAP itself (park -> ring-boundary install
latency, F1 before/after), not the retrain search, so the swap is
injected at a fixed chunk boundary and repeated for a stable latency
estimate.

Asserts (structural, not timing): zero dropped packets across the swap,
exactly one swap recorded with per-backend batch counts summing to the
total, and post-swap F1 recovering on drifted traffic (the phase-A model
degrades, the replacement does not).

  PYTHONPATH=src python -m benchmarks.hot_swap
"""

from __future__ import annotations

import numpy as np

from repro.core import codegen, mlalgos
from repro.data import traffic
from repro.flowstate import StatefulPipeline
from repro.serve import PacketServeEngine, ShardedPacketServeEngine

from benchmarks.common import render_table, save_result

CHUNK = 512
N_PACKETS = 24_000
N_SLOTS = 2048
SPAN_S = 120.0
REPEATS = 3
# swap this many chunks after the drift onset (a patience-like detection
# delay, so the degraded segment is non-empty and deterministic)
DETECT_CHUNKS = 4


def _drift_index(stream) -> int:
    return int(np.searchsorted(stream.times, SPAN_S * traffic.DRIFT_FRAC))


def build_pipelines():
    """(phase-A pipeline, retrained pipeline, stages) — both share the
    FlowStateSpec, so the swap carries the register table bit-identically."""
    stages, names = traffic.flow_feature_stages(n_slots=N_SLOTS)
    train = traffic.make_stream("concept_drift", n_packets=N_PACKETS,
                                seed=0)
    cut = _drift_index(train)
    pipes = []
    for seg in (train.slice(0, cut), train.slice(cut)):
        ds, mu, sd = traffic.stream_feature_dataset(seg, stages, names,
                                                    sample_every=2)
        dnn = mlalgos.train_dnn(ds, hidden=[16, 8], epochs=3, seed=0)
        suffix = traffic.fold_input_standardization(
            codegen.taurus_stages(dnn), mu, sd
        )
        pipes.append(StatefulPipeline(list(stages) + suffix))
    return pipes[0], pipes[1], stages


def serve_with_swap(engine, old_pipe, new_pipe, stream, swap_chunk: int):
    """Serve the stream, swapping at a fixed chunk boundary ->
    (verdicts, stats dict)."""
    verdicts = []
    for i, chunk in enumerate(stream.chunks(CHUNK)):
        if i == swap_chunk:
            engine.swap(new_pipe)
        engine.submit(chunk)
        verdicts.append(engine.flush())
    return np.concatenate(verdicts), engine.stats()


def bench_engine(make_engine, label: str, old_pipe, new_pipe,
                 stream) -> dict:
    drift_idx = _drift_index(stream)
    swap_chunk = drift_idx // CHUNK + DETECT_CHUNKS
    lats, row = [], None
    for _ in range(REPEATS):
        eng = make_engine()
        verdicts, stats = serve_with_swap(eng, old_pipe, new_pipe, stream,
                                          swap_chunk)
        # structural gates: nothing dropped, exactly one swap, and the
        # per-backend batch counts account for every dispatched batch
        assert len(verdicts) == stream.n_packets, (
            f"dropped packets: {len(verdicts)} != {stream.n_packets}"
        )
        assert stats["swaps"] == 1, stats
        assert sum(eng.stats_.backend_batches.values()) == stats["batches"]
        lats.append(stats["swap_lat_ms"][0])
        off = stats["swap_pkt_offsets"][0]
        f1 = mlalgos.f1_score
        row = {
            "engine": label,
            "pipeline": "flow-drift-swap",
            "backend": stats["backend"],
            "depth": stats["depth"],
            "shards": stats["shards"],
            "pkt_per_s": stats["pkt_per_s"],
            "lat_p50_ms": stats["lat_p50_ms"],
            "lat_p95_ms": stats["lat_p95_ms"],
            "lat_p99_ms": stats["lat_p99_ms"],
            "f1_pre_drift": round(f1(stream.labels[:drift_idx],
                                     verdicts[:drift_idx]), 4),
            "f1_post_drift": round(f1(stream.labels[drift_idx:off],
                                      verdicts[drift_idx:off]), 4),
            "f1_post_swap": round(f1(stream.labels[off:], verdicts[off:]),
                                  4),
        }
    row["swap_lat_ms"] = round(float(np.median(lats)), 3)
    # the recovery gate: the swap must matter (structural, not timing)
    assert row["f1_pre_drift"] > 0.85, row
    assert row["f1_post_drift"] < 0.5, row
    assert row["f1_post_swap"] > 0.85, row
    return row


def main() -> dict:
    old_pipe, new_pipe, _stages = build_pipelines()
    stream = traffic.make_stream("concept_drift", n_packets=N_PACKETS,
                                 seed=1)

    feature_dim = len(traffic.COLUMNS)
    rows = [
        bench_engine(
            lambda: PacketServeEngine(old_pipe, feature_dim=feature_dim,
                                      max_batch=CHUNK, depth=2),
            "PacketServeEngine", old_pipe, new_pipe, stream,
        ),
        bench_engine(
            lambda: ShardedPacketServeEngine(
                old_pipe, feature_dim=feature_dim, max_batch=CHUNK,
                depth=2, min_shards=1,
            ),
            "ShardedPacketServeEngine", old_pipe, new_pipe, stream,
        ),
    ]

    print("\n== hot swap: latency + F1 recovery ==")
    print(render_table(
        rows,
        ["engine", "backend", "depth", "shards", "swap_lat_ms",
         "f1_pre_drift", "f1_post_drift", "f1_post_swap", "pkt_per_s"],
    ))

    payload = {
        "n_packets": N_PACKETS,
        "chunk": CHUNK,
        "repeats": REPEATS,
        "serve_stats": rows,
    }
    save_result("hot_swap", payload)
    return payload


if __name__ == "__main__":
    main()
