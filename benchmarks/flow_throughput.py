"""Stateful flow pipeline: interpreter vs Pallas flow-update kernel pkt/s.

Builds the streaming DDoS-burst pipeline (per-flow registers + DNN
classifier, examples/stream_flows.py) and measures end-to-end serving
throughput through ``PacketServeEngine`` on both execution engines, plus
the reaction-time report (packets until each attack flow's first correct
verdict) that the stateless serving path cannot produce at all.

Asserts (the flow-state contract's performance gate):

  * both engines produce bit-identical verdicts on the whole stream;
  * the Pallas engine serves >= the interpreter in pkt/s (best over
    batch sizes and repeats — the kernel's conflict-free round schedule
    must at least match the reference's sequential walk).

  PYTHONPATH=src python -m benchmarks.flow_throughput
"""

from __future__ import annotations

import numpy as np

from repro.core import codegen, mlalgos
from repro.data import traffic
from repro.flowstate import StatefulPipeline
from repro.serve.packet_engine import PacketServeEngine

from benchmarks.common import render_table, save_result

N_PACKETS = 16_000
N_SLOTS = 2048
BATCHES = (256, 512)
REPEATS = 3


def build_pipeline():
    train = traffic.make_stream("ddos_burst", n_packets=N_PACKETS, seed=0)
    stages, names = traffic.flow_feature_stages(n_slots=N_SLOTS)
    ds, mu, sd = traffic.stream_feature_dataset(train, stages, names,
                                                sample_every=2)
    dnn = mlalgos.train_dnn(ds, hidden=[16, 8], epochs=3, seed=0)
    suffix = traffic.fold_input_standardization(
        codegen.taurus_stages(dnn), mu, sd
    )
    return list(stages) + suffix


def serve_once(pipe: StatefulPipeline, stream, max_batch: int):
    """Fresh state, whole stream -> (verdicts, pipeline-only pkt/s, stats)."""
    eng = PacketServeEngine(pipe, feature_dim=len(traffic.COLUMNS),
                            max_batch=max_batch)
    got = [v for v in eng.serve_stream(stream.chunks(max_batch))]
    return np.concatenate(got), eng.stats()["pkt_per_s"], eng.stats()


def main() -> dict:
    stages = build_pipeline()
    stream = traffic.make_stream("ddos_burst", n_packets=N_PACKETS, seed=1)

    rows, verdicts, serve_stats = [], {}, []
    for max_batch in BATCHES:
        best = {}
        for backend in ("interpret", "pallas"):
            pipe = StatefulPipeline(stages, backend=backend)
            pps, best_stats = [], None
            for _ in range(REPEATS):
                v, p, s = serve_once(pipe, stream, max_batch)
                if not pps or p > max(pps):
                    best_stats = s
                pps.append(p)
            verdicts[backend] = v
            best[backend] = max(pps)
            if max_batch == BATCHES[-1]:
                serve_stats.append({
                    "engine": "PacketServeEngine",
                    "pipeline": "flow-ddos",
                    "backend": best_stats["backend"],
                    "depth": best_stats["depth"],
                    "shards": best_stats["shards"],
                    "pkt_per_s": best_stats["pkt_per_s"],
                    "lat_p50_ms": best_stats["lat_p50_ms"],
                    "lat_p95_ms": best_stats["lat_p95_ms"],
                    "lat_p99_ms": best_stats["lat_p99_ms"],
                })
        np.testing.assert_array_equal(
            verdicts["interpret"], verdicts["pallas"],
            err_msg="engines diverged on the stateful pipeline",
        )
        rows.append({
            "batch": max_batch,
            "interp_pps": round(best["interpret"]),
            "pallas_pps": round(best["pallas"]),
            "speedup": round(best["pallas"] / best["interpret"], 2),
        })

    print("\n== stateful flow pipeline: interpreter vs Pallas (pkt/s) ==")
    print(render_table(rows, ["batch", "interp_pps", "pallas_pps",
                              "speedup"]))
    best_ratio = max(r["speedup"] for r in rows)
    assert best_ratio >= 1.0, (
        f"Pallas flow-update kernel slower than the interpreter on the "
        f"stateful pipeline ({best_ratio}x)"
    )

    react = traffic.reaction_report(stream, verdicts["pallas"])
    print("\n== reaction time (DDoS-burst scenario) ==")
    print(f"attack flows        {react['attack_flows']}")
    print(f"detection rate      {react['detection_rate']:.1%}")
    print(f"pkts-to-detection   median {react['reaction_pkts_median']:.0f}"
          f", p95 {react['reaction_pkts_p95']:.0f}")
    print(f"benign FP flows     {react['benign_fp_flow_rate']:.1%}")

    payload = {
        "n_packets": N_PACKETS,
        "n_slots": N_SLOTS,
        "verdicts_match": True,
        "rows": rows,
        "pallas_vs_interp_max_speedup": best_ratio,
        "reaction": react,
        "serve_stats": serve_stats,
    }
    save_result("flow_throughput", payload)
    return payload


if __name__ == "__main__":
    main()
