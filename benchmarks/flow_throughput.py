"""Stateful flow pipeline: interpreter vs fused Pallas single launch pkt/s.

Builds the streaming DDoS-burst pipeline (per-flow registers + DNN
classifier, examples/stream_flows.py) and measures end-to-end serving
throughput through ``PacketServeEngine`` on both execution engines, plus
the reaction-time report (packets until each attack flow's first correct
verdict) that the stateless serving path cannot produce at all.  A
forced-4-device subprocess then serves the same stream through
``ShardedPacketServeEngine`` so BENCH_serve.json carries a real
``shards > 1`` stateful row.

Asserts (the flow-state contract's performance gate):

  * the Pallas pipeline lowers onto the single fused launch
    (``backend == "pallas-fused-flow"``);
  * both engines produce bit-identical verdicts AND bit-identical final
    register state (keys + rows) on the whole stream;
  * the fused engine serves >= FUSED_FLOW_GATE x the interpreter in
    pkt/s (best over batch sizes and repeats).

  PYTHONPATH=src python -m benchmarks.flow_throughput
"""

from __future__ import annotations

import textwrap

import numpy as np

from repro.core import codegen, mlalgos
from repro.data import traffic
from repro.flowstate import StatefulPipeline
from repro.serve.packet_engine import PacketServeEngine

from benchmarks.common import render_table, run_sharded_probe, save_result

N_PACKETS = 16_000
N_SLOTS = 2048
BATCHES = (256, 512)
REPEATS = 3
# the fused single-launch path must beat the interpreter by this factor
# (best over batch sizes and repeats) — the PR-6 perf gate
FUSED_FLOW_GATE = 3.0


def build_pipeline():
    train = traffic.make_stream("ddos_burst", n_packets=N_PACKETS, seed=0)
    stages, names = traffic.flow_feature_stages(n_slots=N_SLOTS)
    ds, mu, sd = traffic.stream_feature_dataset(train, stages, names,
                                                sample_every=2)
    dnn = mlalgos.train_dnn(ds, hidden=[16, 8], epochs=3, seed=0)
    suffix = traffic.fold_input_standardization(
        codegen.taurus_stages(dnn), mu, sd
    )
    return list(stages) + suffix


def build_mat_pipeline(*, mitigated: bool = False):
    """The same flow prefix capped with a range-table (MAT-form) suffix —
    ``Quantize -> LUTGather -> Reduce -> LabelMap`` — and, for the
    ``mitigate-fused`` row, a trailing ``Mitigate`` action table.  Both
    shapes must lower onto the ONE fused launch (the widened envelope)."""
    from repro.core import stageir
    from repro.flowstate import MitigationSpec

    (fk, ru, ws), _names = traffic.flow_feature_stages(n_slots=N_SLOTS)
    rng = np.random.default_rng(7)
    n_in = ws.n_out
    edges = np.sort(rng.random((n_in, 7)).astype(np.float32), axis=1)
    edges[0] = np.arange(1.0, 8.0, dtype=np.float32)   # raw packet count
    tables = rng.random((n_in, 8, 4)).astype(np.float32)
    stages = [fk, ru, ws, stageir.Quantize(edges),
              stageir.LUTGather(tables), stageir.Reduce("argmax"),
              stageir.LabelMap(np.asarray([0, 1, 1, 0], np.int32))]
    if mitigated:
        stages.append(stageir.Mitigate(
            MitigationSpec(n_slots=N_SLOTS, threshold=6)))
    return stages


def serve_once(pipe: StatefulPipeline, stream, max_batch: int):
    """Fresh state, whole stream -> (verdicts, pipeline-only pkt/s, stats,
    final FlowState)."""
    eng = PacketServeEngine(pipe, feature_dim=len(traffic.COLUMNS),
                            max_batch=max_batch)
    got = [v for v in eng.serve_stream(stream.chunks(max_batch))]
    return np.concatenate(got), eng.stats()["pkt_per_s"], eng.stats(), \
        eng.state


def fused_suffix_rows(stream) -> tuple[list[dict], list[dict]]:
    """The widened-envelope rows: a MAT-suffixed pipeline and a
    mitigated MAT pipeline, each required to (a) report the single
    fused launch, (b) serve bit-identically to the interpreter, and
    (c) clear FUSED_FLOW_GATE — the caller asserts (c) after the
    artifact is saved.  Returns (table rows, BENCH_serve entries)."""
    rows, stats_rows = [], []
    for name, stages in (("mat-fused", build_mat_pipeline()),
                         ("mitigate-fused",
                          build_mat_pipeline(mitigated=True))):
        pipes = {b: StatefulPipeline(stages, backend=b)
                 for b in ("interpret", "pallas")}
        assert pipes["pallas"].backend == "pallas-fused-flow", (
            f"{name}: expected the single fused launch, got "
            f"{pipes['pallas'].backend!r} "
            f"(reason: {pipes['pallas'].fallback_reason})"
        )
        best, verd, stats = {}, {}, {}
        # same gate semantics as the base rows: best over batch sizes
        # AND repeats
        for backend in ("interpret", "pallas"):
            pps, best_stats, best_last = [], None, 0.0
            for max_batch in BATCHES:
                for _ in range(REPEATS):
                    v, p, s, _fs = serve_once(pipes[backend], stream,
                                              max_batch)
                    if max_batch == BATCHES[-1] and p > best_last:
                        best_stats, best_last = s, p
                    pps.append(p)
                verd.setdefault(backend, {})[max_batch] = v
            best[backend] = max(pps)
            stats[backend] = best_stats
        for max_batch in BATCHES:
            np.testing.assert_array_equal(
                verd["interpret"][max_batch], verd["pallas"][max_batch],
                err_msg=f"{name}: engines diverged (batch {max_batch})")
        rows.append({
            "pipeline": name,
            "interp_pps": round(best["interpret"]),
            "pallas_pps": round(best["pallas"]),
            "speedup": round(best["pallas"] / best["interpret"], 2),
        })
        stats_rows.append({
            "engine": "PacketServeEngine",
            "pipeline": name,
            "backend": stats["pallas"]["backend"],
            "depth": stats["pallas"]["depth"],
            "shards": stats["pallas"]["shards"],
            "pkt_per_s": stats["pallas"]["pkt_per_s"],
            "lat_p50_ms": stats["pallas"]["lat_p50_ms"],
            "lat_p95_ms": stats["pallas"]["lat_p95_ms"],
            "lat_p99_ms": stats["pallas"]["lat_p99_ms"],
        })
    return rows, stats_rows


# serves the SAME stream through ShardedPacketServeEngine under 4 forced
# host devices (run_sharded_probe) — the shards>1 stateful trajectory row
_SHARDED_SCRIPT = textwrap.dedent(f"""
    import json
    import jax
    from benchmarks.flow_throughput import N_PACKETS, build_pipeline
    from repro.data import traffic
    from repro.flowstate import StatefulPipeline
    from repro.serve import ShardedPacketServeEngine

    assert len(jax.devices()) == 4, jax.devices()
    pipe = StatefulPipeline(build_pipeline(), backend="pallas")
    assert pipe.backend == "pallas-fused-flow", pipe.backend
    stream = traffic.make_stream("ddos_burst", n_packets=N_PACKETS, seed=1)
    eng = ShardedPacketServeEngine(pipe, feature_dim=len(traffic.COLUMNS),
                                   max_batch=512)
    assert eng.sharded and eng.n_shards == 4, (eng.sharded, eng.n_shards)
    # one warm pass compiles the shard_map step; the SAME engine then
    # serves the stream {REPEATS} more times so the recorded stats
    # amortize the compile out of the steady-state rate
    for _ in range(1 + {REPEATS}):
        for _v in eng.serve_stream(stream.chunks(512)):
            pass
    print("SHARDED-STATS " + json.dumps(eng.stats()))
""")


def sharded_stateful_stat() -> dict:
    """One BENCH_serve entry for the fused stateful pipeline served by
    ``ShardedPacketServeEngine`` across 4 forced host devices; the
    ``shards`` field records the actual device count of the run."""
    s = run_sharded_probe(_SHARDED_SCRIPT)
    assert s["shards"] > 1, f"sharded probe degraded to {s['shards']} shard"
    return {
        "engine": "ShardedPacketServeEngine",
        "pipeline": "flow-ddos",
        "backend": s["backend"],
        "depth": s["depth"],
        "shards": s["shards"],
        "pkt_per_s": s["pkt_per_s"],
        "lat_p50_ms": s["lat_p50_ms"],
        "lat_p95_ms": s["lat_p95_ms"],
        "lat_p99_ms": s["lat_p99_ms"],
    }


def main() -> dict:
    stages = build_pipeline()
    stream = traffic.make_stream("ddos_burst", n_packets=N_PACKETS, seed=1)

    pipes = {b: StatefulPipeline(stages, backend=b)
             for b in ("interpret", "pallas")}
    assert pipes["pallas"].backend == "pallas-fused-flow", (
        f"the DDoS pipeline must lower onto the single fused launch, "
        f"got {pipes['pallas'].backend!r}"
    )

    rows, verdicts, states, serve_stats = [], {}, {}, []
    for max_batch in BATCHES:
        best = {}
        for backend in ("interpret", "pallas"):
            pipe = pipes[backend]
            pps, best_stats = [], None
            for _ in range(REPEATS):
                v, p, s, fs = serve_once(pipe, stream, max_batch)
                if not pps or p > max(pps):
                    best_stats = s
                pps.append(p)
            verdicts[backend] = v
            states[backend] = fs
            best[backend] = max(pps)
            if max_batch == BATCHES[-1]:
                serve_stats.append({
                    "engine": "PacketServeEngine",
                    "pipeline": "flow-ddos",
                    "backend": best_stats["backend"],
                    "depth": best_stats["depth"],
                    "shards": best_stats["shards"],
                    "pkt_per_s": best_stats["pkt_per_s"],
                    "lat_p50_ms": best_stats["lat_p50_ms"],
                    "lat_p95_ms": best_stats["lat_p95_ms"],
                    "lat_p99_ms": best_stats["lat_p99_ms"],
                })
        np.testing.assert_array_equal(
            verdicts["interpret"], verdicts["pallas"],
            err_msg="engines diverged on the stateful pipeline",
        )
        # final register state is part of the contract too: the fused
        # launch must leave the SAME table (keys + rows, bit for bit) as
        # the scan reference after the whole stream
        np.testing.assert_array_equal(
            np.asarray(states["interpret"].keys),
            np.asarray(states["pallas"].keys),
            err_msg="final register keys diverged",
        )
        np.testing.assert_array_equal(
            np.asarray(states["interpret"].regs),
            np.asarray(states["pallas"].regs),
            err_msg="final register rows diverged",
        )
        rows.append({
            "batch": max_batch,
            "interp_pps": round(best["interpret"]),
            "pallas_pps": round(best["pallas"]),
            "speedup": round(best["pallas"] / best["interpret"], 2),
        })

    print("\n== stateful flow pipeline: interpreter vs Pallas (pkt/s) ==")
    print(render_table(rows, ["batch", "interp_pps", "pallas_pps",
                              "speedup"]))
    best_ratio = max(r["speedup"] for r in rows)

    # widened fused envelope: MAT suffix + in-kernel mitigation rows
    sfx_rows, sfx_stats = fused_suffix_rows(stream)
    serve_stats.extend(sfx_stats)
    print("\n== widened fused envelope: MAT / mitigated suffixes ==")
    print(render_table(sfx_rows, ["pipeline", "interp_pps", "pallas_pps",
                                  "speedup"]))

    # multi-device stateful trajectory row (forced-4-device subprocess)
    serve_stats.append(sharded_stateful_stat())
    print("\n== serving-engine stats (BENCH_serve entries) ==")
    print(render_table(
        serve_stats,
        ["engine", "pipeline", "backend", "depth", "shards", "pkt_per_s",
         "lat_p50_ms", "lat_p95_ms", "lat_p99_ms"],
    ))

    react = traffic.reaction_report(stream, verdicts["pallas"])
    print("\n== reaction time (DDoS-burst scenario) ==")
    print(f"attack flows        {react['attack_flows']}")
    print(f"detection rate      {react['detection_rate']:.1%}")
    print(f"pkts-to-detection   median {react['reaction_pkts_median']:.0f}"
          f", p95 {react['reaction_pkts_p95']:.0f}")
    print(f"benign FP flows     {react['benign_fp_flow_rate']:.1%}")

    payload = {
        "n_packets": N_PACKETS,
        "n_slots": N_SLOTS,
        "verdicts_match": True,
        "final_state_match": True,
        "fused_backend": pipes["pallas"].backend,
        "rows": rows,
        "fused_suffix_rows": sfx_rows,
        "pallas_vs_interp_max_speedup": best_ratio,
        "fused_flow_gate": FUSED_FLOW_GATE,
        "reaction": react,
        "serve_stats": serve_stats,
    }
    save_result("flow_throughput", payload)

    # the timing gates LAST, after the artifact records the measured
    # numbers — a flaky shared-runner measurement must fail the gate,
    # not erase the trajectory entry
    assert best_ratio >= FUSED_FLOW_GATE, (
        f"fused stateful launch below the {FUSED_FLOW_GATE}x gate vs the "
        f"interpreter ({best_ratio}x best over batches/repeats)"
    )
    for r in sfx_rows:
        assert r["speedup"] >= FUSED_FLOW_GATE, (
            f"{r['pipeline']}: fused launch below the {FUSED_FLOW_GATE}x "
            f"gate vs the interpreter ({r['speedup']}x)"
        )
    return payload


if __name__ == "__main__":
    main()
