"""Whole-DAG JIT vs interpreted chaining vs Pallas backends: pkt/s bench.

Builds a 3-model chain (DNN gate > SVM | KMeans) on the AD dataset, then
measures end-to-end packet throughput:

  * interpreted — ``chaining.run_dag``: each model's pipeline runs as its
    own jitted call, verdicts merge in numpy between stages;
  * compiled    — ``chaining.compile_dag``: the whole DAG is ONE jitted
    XLA program (stage lists inlined, gating as jnp.where masks);
  * pallas      — ``compile_dag(..., backend="pallas")``: kernel-eligible
    pipelines inside the DAG run as fused Pallas kernel launches
    (docs/pipeline_ir.md#pallas-lowering-contract).

A second table pins the fused-DAG megakernel on the chained AD > TC
pipeline: ``backend="pallas"`` fuses the whole DAG into ONE kernel launch
(``pallas-fused-dag``) and must serve >= 1.5x the per-model-launch
baseline (``fuse_dag=False`` — the PR-4 path) in pkt/s, bit-exact vs
``run_dag``.  A third table pins the per-pipeline Pallas >= interpreter
gate on the fused-MLP pipeline.  All comparisons use best-of-rounds
timing (shared-runner noise).  Serve-engine stats (pkt/s + latency
percentiles per engine x backend) are recorded for the consolidated
``BENCH_serve.json`` that ``benchmarks/run.py`` emits.

  PYTHONPATH=src python -m benchmarks.dag_throughput
"""

from __future__ import annotations

from typing import Callable

import numpy as np

from repro.core import chaining, codegen, feasibility as feas, mlalgos
from repro.core.alchemy import Model
from repro.data import netdata
from repro.serve import PacketServeEngine, ShardedPacketServeEngine

from benchmarks.common import (
    bench_pps,
    bench_pps_best,
    render_table,
    run_sharded_probe,
    save_result,
)

BATCHES = (256, 1024, 4096)
# the megakernel's biggest win is launch-overhead-dominated small
# micro-batches (the latency-bound serving regime), so its table starts
# one step lower
FUSED_BATCHES = (128, 256, 1024, 4096)
REPEATS = 20
FUSED_DAG_GATE = 1.5               # megakernel vs per-model-launch baseline


def _noop_loader():
    return None


def _leaf(name: str) -> Model:
    return Model({"name": name, "data_loader": _noop_loader,
                  "algorithm": None})


def build_chain(seed: int = 0):
    d = netdata.make_ad_dataset(features=7, n_train=4096, n_test=8192)
    rep = feas.FeasibilityReport(True, [], {"cu": 1}, 1.0, 1e9)
    dnn = mlalgos.train_dnn(d, hidden=[16, 8], epochs=4, seed=seed)
    svm = mlalgos.train_svm(d, epochs=6, seed=seed)
    km = mlalgos.train_kmeans(d, k=4, seed=seed)
    pipes = {
        "ad": codegen.taurus_codegen("ad", dnn, rep),
        "tc": codegen.taurus_codegen("tc", svm, rep),
        "cl": codegen.taurus_codegen("cl", km, rep),
    }
    node = _leaf("ad") > (_leaf("tc") | _leaf("cl"))
    return d, node, pipes


def bench(fn, X, repeats: int = REPEATS) -> float:
    return bench_pps(fn, X, repeats)


def _serve_stat(pipeline, d, *, label: str, engine_cls=PacketServeEngine,
                max_batch: int = 1024, depth: int = 2, passes: int = 3
                ) -> dict:
    """Stream the test set through a serving engine; -> one BENCH_serve
    entry (pkt/s + p50/p95/p99 pipeline latency)."""
    eng = engine_cls(pipeline, feature_dim=d.num_features,
                     max_batch=max_batch, depth=depth)
    chunks = [d.test_x[s:s + 997] for s in range(0, len(d.test_x), 997)]
    for _ in range(passes):
        for _v in eng.serve_stream(iter(chunks)):
            pass
    s = eng.stats()
    return {
        "engine": engine_cls.__name__,
        "pipeline": label,
        "backend": s["backend"],
        "depth": s["depth"],
        "shards": s["shards"],
        "pkt_per_s": s["pkt_per_s"],
        "lat_p50_ms": s["lat_p50_ms"],
        "lat_p95_ms": s["lat_p95_ms"],
        "lat_p99_ms": s["lat_p99_ms"],
    }


_SHARDED_DAG_SCRIPT = """
import json
import jax
assert len(jax.devices()) == 4, jax.devices()
from benchmarks.dag_throughput import _leaf, _serve_stat, build_chain
from repro.core import chaining
from repro.serve import ShardedPacketServeEngine
d, node, pipes = build_chain()
stat = _serve_stat(
    chaining.compile_dag(_leaf("ad") > _leaf("tc"), pipes,
                         backend="pallas"),
    d, label="ad>tc", engine_cls=ShardedPacketServeEngine)
assert stat["shards"] == 4, stat
print("SHARDED-STATS " + json.dumps(stat))
"""


def _sharded_serve_stat(d, pipes) -> dict:
    """The ShardedPacketServeEngine row, measured in a forced-4-device
    subprocess so ``shards`` records the actual device count (an
    in-process run on a one-device host degrades to the base engine and
    would claim a sharded number it never earned).  Falls back to the
    honest degraded in-process row if the probe cannot run."""
    try:
        return run_sharded_probe(_SHARDED_DAG_SCRIPT)
    except Exception as e:  # noqa: BLE001 — probe is environment-bound
        print(f"sharded probe unavailable ({e}); recording the "
              f"in-process (degraded) row")
        return _serve_stat(
            chaining.compile_dag(_leaf("ad") > _leaf("tc"), pipes,
                                 backend="pallas"),
            d, label="ad>tc", engine_cls=ShardedPacketServeEngine)


def bench_fused_dag(d, pipes) -> dict:
    """The megakernel tables: chained AD > TC, one launch vs per-model.

    Two comparisons, both bit-exact vs ``run_dag``:

    * **direct calls** — the megakernel launch alone must not lose to
      per-model launches (>= 1x at its best batch; the kernel-level
      honesty gate);
    * **serving path** — the PR's hot path (megakernel + overlap engine,
      ``depth>1``) vs the PR-4 serving baseline (per-model launches,
      synchronous ``depth=1`` engine) must reach ``FUSED_DAG_GATE`` pkt/s
      at its best micro-batch.  Baseline and new path are timed in
      interleaved rounds so load drift on shared runners hits both."""
    import time as _time

    node = _leaf("ad") > _leaf("tc")
    per_model = chaining.compile_dag(node, pipes, backend="pallas",
                                     fuse_dag=False)
    fused = chaining.compile_dag(node, pipes, backend="pallas")
    assert fused.backend == "pallas-fused-dag", (
        f"AD > TC must fuse into the megakernel, got {fused.backend}"
    )
    assert per_model.backend == "pallas", per_model.backend

    ref = chaining.run_dag(node, pipes, d.test_x)
    assert np.array_equal(ref, fused(d.test_x)), "megakernel diverged"
    assert np.array_equal(ref, per_model(d.test_x)), "per-model diverged"

    rows = []
    for n in FUSED_BATCHES:
        X = d.test_x[:n]
        base_pps = bench_pps_best(per_model, X)
        mega_pps = bench_pps_best(fused, X)
        rows.append({
            "batch": n,
            "permodel_pps": round(base_pps),
            "megakernel_pps": round(mega_pps),
            "speedup": round(mega_pps / base_pps, 2),
        })

    print("\n== fused-DAG megakernel vs per-model launches "
          "(AD > TC, direct calls, pkt/s) ==")
    print(render_table(
        rows, ["batch", "permodel_pps", "megakernel_pps", "speedup"]
    ))
    best_direct = max(r["speedup"] for r in rows)

    # ---- serving path: overlap engine + megakernel vs PR-4 baseline
    stream = np.concatenate([d.test_x] * 4)
    chunks = [stream[s:s + 2048] for s in range(0, len(stream), 2048)]

    def engine_pps(dag, depth: int, max_batch: int) -> Callable[[], float]:
        eng = PacketServeEngine(dag, feature_dim=d.num_features,
                                max_batch=max_batch, depth=depth)

        def one_round() -> float:
            t0 = _time.perf_counter()
            n = 0
            for v in eng.serve_stream(iter(chunks)):
                n += len(v)
            return n / (_time.perf_counter() - t0)

        return one_round

    serve_rows = []
    for max_batch, depth in ((256, 2), (512, 2), (1024, 3), (2048, 3)):
        base_round = engine_pps(per_model, 1, max_batch)
        new_round = engine_pps(fused, depth, max_batch)
        base_pps = mega_pps = 0.0
        for _ in range(4):                      # interleaved best-of
            base_pps = max(base_pps, base_round())
            mega_pps = max(mega_pps, new_round())
        serve_rows.append({
            "max_batch": max_batch,
            "depth": depth,
            "pr4_sync_pps": round(base_pps),
            "fused_overlap_pps": round(mega_pps),
            "speedup": round(mega_pps / base_pps, 2),
        })

    print("\n== serving path: megakernel + overlap engine vs PR-4 "
          "per-model sync engine (pkt/s) ==")
    print(render_table(
        serve_rows,
        ["max_batch", "depth", "pr4_sync_pps", "fused_overlap_pps",
         "speedup"],
    ))
    best_serve = max(r["speedup"] for r in serve_rows)
    return {
        "schedule": fused.schedule,
        "rows": rows,
        "serve_rows": serve_rows,
        "max_speedup_direct": best_direct,
        "max_speedup": best_serve,
        "bit_exact_vs_run_dag": True,
    }


def main() -> dict:
    d, node, pipes = build_chain()
    dag = chaining.compile_dag(node, pipes)
    dag_pallas = chaining.compile_dag(node, pipes, backend="pallas")

    ver_eager = chaining.run_dag(node, pipes, d.test_x)
    ver_jit = dag(d.test_x)
    assert np.array_equal(ver_eager, ver_jit), "compiled DAG diverged"
    assert np.array_equal(ver_eager, dag_pallas(d.test_x)), \
        "pallas DAG diverged"

    rows = []
    for n in BATCHES:
        X = d.test_x[:n]
        interp = bench(lambda x: chaining.run_dag(node, pipes, x), X)
        whole = bench(dag, X)
        pallas = bench(dag_pallas, X)
        eng = PacketServeEngine(dag_pallas, feature_dim=d.num_features,
                                max_batch=n)

        def served(x, _e=eng):
            _e.submit(x)
            return _e.flush()

        engine = bench(served, X)
        rows.append({
            "batch": n,
            "interp_pps": round(interp),
            "dagjit_pps": round(whole),
            "pallas_pps": round(pallas),
            "engine_pps": round(engine),
            "dagjit_x": round(whole / interp, 2),    # the PR-1 baseline ratio
            "pallas_x": round(pallas / interp, 2),
        })

    print("\n== whole-DAG JIT vs interpreted chaining (pkt/s) ==")
    print(render_table(
        rows, ["batch", "interp_pps", "dagjit_pps", "pallas_pps",
               "engine_pps", "dagjit_x", "pallas_x"]
    ))

    # the megakernel gate (chained AD > TC, acceptance: >= 1.5x per-model)
    fused_dag = bench_fused_dag(d, pipes)

    # per-pipeline backend gate: the fused-MLP (DNN) pipeline served by the
    # Pallas backend must beat the interpreted stage-apply path
    from repro.core import stageir

    stages = pipes["ad"].stages
    run_interp = stageir.compile_stages(stages, backend="interpret")
    run_pallas = stageir.compile_stages(stages, backend="pallas")
    assert run_pallas.backend == "pallas", "DNN pipeline must lower to pallas"
    X = d.test_x[:BATCHES[-1]]
    assert np.array_equal(np.asarray(run_interp(X)),
                          np.asarray(run_pallas(X))), "pallas diverged"
    backend_rows = []
    for n in BATCHES:
        Xn = d.test_x[:n]
        ipps = bench(lambda x: np.asarray(run_interp(x)), Xn)
        ppps = bench(lambda x: np.asarray(run_pallas(x)), Xn)
        backend_rows.append({
            "batch": n,
            "interp_pps": round(ipps),
            "pallas_pps": round(ppps),
            "speedup": round(ppps / ipps, 2),
        })
    print("\n== fused-MLP pipeline: interpreter vs Pallas backend (pkt/s) ==")
    print(render_table(
        backend_rows, ["batch", "interp_pps", "pallas_pps", "speedup"]
    ))
    best = max(r["speedup"] for r in backend_rows)

    # serve-engine stats per engine x backend for BENCH_serve.json
    ad_tc = _leaf("ad") > _leaf("tc")
    serve_stats = [
        _serve_stat(chaining.compile_dag(ad_tc, pipes), d,
                    label="ad>tc"),
        _serve_stat(chaining.compile_dag(ad_tc, pipes, backend="pallas",
                                         fuse_dag=False), d,
                    label="ad>tc"),
        _serve_stat(chaining.compile_dag(ad_tc, pipes, backend="pallas"), d,
                    label="ad>tc"),
        _sharded_serve_stat(d, pipes),
    ]
    print("\n== serving-engine stats (BENCH_serve entries) ==")
    print(render_table(
        serve_stats,
        ["engine", "pipeline", "backend", "depth", "shards", "pkt_per_s",
         "lat_p50_ms", "lat_p95_ms", "lat_p99_ms"],
    ))

    payload = {
        "schedule": dag.schedule,
        "verdicts_match": True,
        "model_backends": dag_pallas.model_backends,
        "rows": rows,
        "fused_dag": fused_dag,
        "backend_rows": backend_rows,
        # same definition as the PR-1 baseline: whole-DAG jit vs interpreted
        "max_speedup": max(r["dagjit_x"] for r in rows),
        "pallas_vs_interp_max_speedup": best,
        "fused_dag_vs_permodel_max_speedup": fused_dag["max_speedup"],
        "serve_stats": serve_stats,
    }
    save_result("dag_throughput", payload)

    # timing gates LAST, after the artifact records the measured numbers
    # — a flaky shared-runner measurement must fail the gate, not erase
    # the trajectory entry
    assert fused_dag["max_speedup_direct"] >= 1.0, (
        f"fused-DAG megakernel slower than per-model launches at every "
        f"batch size ({fused_dag['max_speedup_direct']}x)"
    )
    assert best >= 1.0, (
        f"Pallas backend slower than the interpreter on the fused-MLP "
        f"pipeline ({best}x)"
    )
    assert fused_dag["max_speedup"] >= FUSED_DAG_GATE, (
        f"fused-DAG serving path only {fused_dag['max_speedup']}x the "
        f"PR-4 per-model-launch baseline (gate {FUSED_DAG_GATE}x)"
    )
    return payload


if __name__ == "__main__":
    main()
