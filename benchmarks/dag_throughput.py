"""Whole-DAG JIT vs interpreted chaining vs Pallas backend: pkt/s bench.

Builds a 3-model chain (DNN gate > SVM | KMeans) on the AD dataset, then
measures end-to-end packet throughput three ways:

  * interpreted — ``chaining.run_dag``: each model's pipeline runs as its
    own jitted call, verdicts merge in numpy between stages;
  * compiled    — ``chaining.compile_dag``: the whole DAG is ONE jitted
    XLA program (stage lists inlined, gating as jnp.where masks);
  * pallas      — ``compile_dag(..., backend="pallas")``: kernel-eligible
    pipelines inside the DAG run as fused Pallas kernel launches
    (docs/pipeline_ir.md#pallas-lowering-contract).

All paths produce bit-identical verdicts (asserted).  A second table pins
the per-pipeline contract on the fused-MLP (DNN) pipeline: the Pallas
backend must serve >= the interpreted stage-apply path in pkt/s (asserted —
this is the ROADMAP "fast as the hardware allows" gate).  Emits JSON like
the other benches.

  PYTHONPATH=src python -m benchmarks.dag_throughput
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import chaining, codegen, feasibility as feas, mlalgos
from repro.core.alchemy import Model
from repro.data import netdata
from repro.serve.packet_engine import PacketServeEngine

from benchmarks.common import bench_pps, render_table, save_result

BATCHES = (256, 1024, 4096)
REPEATS = 20


def _noop_loader():
    return None


def _leaf(name: str) -> Model:
    return Model({"name": name, "data_loader": _noop_loader,
                  "algorithm": None})


def build_chain(seed: int = 0):
    d = netdata.make_ad_dataset(features=7, n_train=4096, n_test=8192)
    rep = feas.FeasibilityReport(True, [], {"cu": 1}, 1.0, 1e9)
    dnn = mlalgos.train_dnn(d, hidden=[16, 8], epochs=4, seed=seed)
    svm = mlalgos.train_svm(d, epochs=6, seed=seed)
    km = mlalgos.train_kmeans(d, k=4, seed=seed)
    pipes = {
        "ad": codegen.taurus_codegen("ad", dnn, rep),
        "tc": codegen.taurus_codegen("tc", svm, rep),
        "cl": codegen.taurus_codegen("cl", km, rep),
    }
    node = _leaf("ad") > (_leaf("tc") | _leaf("cl"))
    return d, node, pipes


def bench(fn, X, repeats: int = REPEATS) -> float:
    return bench_pps(fn, X, repeats)


def main() -> dict:
    d, node, pipes = build_chain()
    dag = chaining.compile_dag(node, pipes)
    dag_pallas = chaining.compile_dag(node, pipes, backend="pallas")

    ver_eager = chaining.run_dag(node, pipes, d.test_x)
    ver_jit = dag(d.test_x)
    assert np.array_equal(ver_eager, ver_jit), "compiled DAG diverged"
    assert np.array_equal(ver_eager, dag_pallas(d.test_x)), \
        "pallas DAG diverged"

    rows = []
    for n in BATCHES:
        X = d.test_x[:n]
        interp = bench(lambda x: chaining.run_dag(node, pipes, x), X)
        whole = bench(dag, X)
        pallas = bench(dag_pallas, X)
        eng = PacketServeEngine(dag_pallas, feature_dim=d.num_features,
                                max_batch=n)

        def served(x, _e=eng):
            _e.submit(x)
            return _e.flush()

        engine = bench(served, X)
        rows.append({
            "batch": n,
            "interp_pps": round(interp),
            "dagjit_pps": round(whole),
            "pallas_pps": round(pallas),
            "engine_pps": round(engine),
            "dagjit_x": round(whole / interp, 2),    # the PR-1 baseline ratio
            "pallas_x": round(pallas / interp, 2),
        })

    print("\n== whole-DAG JIT vs interpreted chaining (pkt/s) ==")
    print(render_table(
        rows, ["batch", "interp_pps", "dagjit_pps", "pallas_pps",
               "engine_pps", "dagjit_x", "pallas_x"]
    ))

    # per-pipeline backend gate: the fused-MLP (DNN) pipeline served by the
    # Pallas backend must beat the interpreted stage-apply path
    from repro.core import stageir

    stages = pipes["ad"].stages
    run_interp = stageir.compile_stages(stages, backend="interpret")
    run_pallas = stageir.compile_stages(stages, backend="pallas")
    assert run_pallas.backend == "pallas", "DNN pipeline must lower to pallas"
    X = d.test_x[:BATCHES[-1]]
    assert np.array_equal(np.asarray(run_interp(X)),
                          np.asarray(run_pallas(X))), "pallas diverged"
    backend_rows = []
    for n in BATCHES:
        Xn = d.test_x[:n]
        ipps = bench(lambda x: np.asarray(run_interp(x)), Xn)
        ppps = bench(lambda x: np.asarray(run_pallas(x)), Xn)
        backend_rows.append({
            "batch": n,
            "interp_pps": round(ipps),
            "pallas_pps": round(ppps),
            "speedup": round(ppps / ipps, 2),
        })
    print("\n== fused-MLP pipeline: interpreter vs Pallas backend (pkt/s) ==")
    print(render_table(
        backend_rows, ["batch", "interp_pps", "pallas_pps", "speedup"]
    ))
    best = max(r["speedup"] for r in backend_rows)
    assert best >= 1.0, (
        f"Pallas backend slower than the interpreter on the fused-MLP "
        f"pipeline ({best}x)"
    )

    payload = {
        "schedule": dag.schedule,
        "verdicts_match": True,
        "model_backends": dag_pallas.model_backends,
        "rows": rows,
        "backend_rows": backend_rows,
        # same definition as the PR-1 baseline: whole-DAG jit vs interpreted
        "max_speedup": max(r["dagjit_x"] for r in rows),
        "pallas_vs_interp_max_speedup": best,
    }
    save_result("dag_throughput", payload)
    return payload


if __name__ == "__main__":
    main()
