"""Whole-DAG JIT vs interpreted chaining: packets/sec microbench.

Builds a 3-model chain (DNN gate > SVM | KMeans) on the AD dataset, then
measures end-to-end packet throughput two ways:

  * interpreted — ``chaining.run_dag``: each model's pipeline runs as its
    own jitted call, verdicts merge in numpy between stages;
  * compiled    — ``chaining.compile_dag``: the whole DAG is ONE jitted
    XLA program (stage lists inlined, gating as jnp.where masks).

Both paths produce bit-identical verdicts (asserted); the delta is pure
dispatch/glue overhead removed by whole-DAG compilation.  Emits JSON like
the other benches.

  PYTHONPATH=src python -m benchmarks.dag_throughput
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import chaining, codegen, feasibility as feas, mlalgos
from repro.core.alchemy import Model
from repro.data import netdata
from repro.serve.packet_engine import PacketServeEngine

from benchmarks.common import Timer, render_table, save_result

BATCHES = (256, 1024, 4096)
REPEATS = 20


def _noop_loader():
    return None


def _leaf(name: str) -> Model:
    return Model({"name": name, "data_loader": _noop_loader,
                  "algorithm": None})


def build_chain(seed: int = 0):
    d = netdata.make_ad_dataset(features=7, n_train=4096, n_test=8192)
    rep = feas.FeasibilityReport(True, [], {"cu": 1}, 1.0, 1e9)
    dnn = mlalgos.train_dnn(d, hidden=[16, 8], epochs=4, seed=seed)
    svm = mlalgos.train_svm(d, epochs=6, seed=seed)
    km = mlalgos.train_kmeans(d, k=4, seed=seed)
    pipes = {
        "ad": codegen.taurus_codegen("ad", dnn, rep),
        "tc": codegen.taurus_codegen("tc", svm, rep),
        "cl": codegen.taurus_codegen("cl", km, rep),
    }
    node = _leaf("ad") > (_leaf("tc") | _leaf("cl"))
    return d, node, pipes


def bench(fn, X, repeats: int = REPEATS) -> float:
    fn(X)  # warm-up / compile
    with Timer() as t:
        for _ in range(repeats):
            fn(X)
    return repeats * len(X) / t.wall_s


def main() -> dict:
    d, node, pipes = build_chain()
    dag = chaining.compile_dag(node, pipes)

    ver_eager = chaining.run_dag(node, pipes, d.test_x)
    ver_jit = dag(d.test_x)
    assert np.array_equal(ver_eager, ver_jit), "compiled DAG diverged"

    rows = []
    for n in BATCHES:
        X = d.test_x[:n]
        interp = bench(lambda x: chaining.run_dag(node, pipes, x), X)
        whole = bench(dag, X)
        eng = PacketServeEngine(dag, feature_dim=d.num_features, max_batch=n)

        def served(x, _e=eng):
            _e.submit(x)
            return _e.flush()

        engine = bench(served, X)
        rows.append({
            "batch": n,
            "interp_pps": round(interp),
            "dagjit_pps": round(whole),
            "engine_pps": round(engine),
            "speedup": round(whole / interp, 2),
        })

    print("\n== whole-DAG JIT vs interpreted chaining (pkt/s) ==")
    print(render_table(
        rows, ["batch", "interp_pps", "dagjit_pps", "engine_pps", "speedup"]
    ))
    payload = {
        "schedule": dag.schedule,
        "verdicts_match": True,
        "rows": rows,
        "max_speedup": max(r["speedup"] for r in rows),
    }
    save_result("dag_throughput", payload)
    return payload


if __name__ == "__main__":
    main()
