"""Telemetry overhead gate: pkt/s with the full plane on vs off.

Serves the streaming DDoS-burst flow pipeline (flow_throughput's
``build_pipeline``, the fused Pallas launch) through two otherwise
identical ``PacketServeEngine`` instances — one constructed with
``telemetry=False``, one with the full telemetry plane (metrics + spans +
segmentation stats + flush-boundary health scans) — and compares
steady-state throughput.  The stateful pipeline is deliberately the
subject: it exercises EVERY recording site, including the host-side
slot-segmentation recompute, so the gate bounds the worst case.

Methodology: rounds run INTERLEAVED (off, on, off, on, …) so that
machine-wide drift — thermal state, background load on a shared runner —
hits both sides equally, and the gate statistic is the BEST adjacent-pair
``on/off`` ratio.  Round-to-round noise on shared CPU runners is +-5%
(measured: identical engines differ that much run to run; the recorded
``dispatch_s`` is bit-close between modes), so a best-vs-best comparison
flakes while a genuine K% slowdown shifts EVERY pair down by K% and still
fails the best-pair gate.

Asserts (the telemetry contract's overhead budget,
docs/pipeline_ir.md#telemetry-contract):

  * best paired-round on/off throughput ratio >= TELEMETRY_OVERHEAD_GATE;
  * verdicts are bit-identical with telemetry on and off (observation
    never perturbs the data path);
  * the recorded packet counter equals the packets actually served.

  PYTHONPATH=src python -m benchmarks.telemetry_overhead
"""

from __future__ import annotations

import numpy as np

from repro.data import traffic
from repro.flowstate import StatefulPipeline
from repro.serve.packet_engine import PacketServeEngine

from benchmarks.common import render_table, save_result
from benchmarks.flow_throughput import N_PACKETS, build_pipeline

MAX_BATCH = 512
ROUNDS = 6
# full telemetry must keep at least this fraction of bare throughput
# (best interleaved round pair — see the methodology note above)
TELEMETRY_OVERHEAD_GATE = 0.97


def _make_engine(stages, telemetry):
    pipe = StatefulPipeline(stages, backend="pallas")
    return PacketServeEngine(pipe, feature_dim=len(traffic.COLUMNS),
                             max_batch=MAX_BATCH, telemetry=telemetry)


def _one_round(eng, stream) -> tuple[float, np.ndarray]:
    """One steady-state pass: pkt/s from the stats delta + verdicts."""
    p0, w0 = eng.stats_.packets, eng.stats_.wall_s
    verdicts = np.concatenate(
        list(eng.serve_stream(stream.chunks(MAX_BATCH))))
    rate = (eng.stats_.packets - p0) / max(eng.stats_.wall_s - w0, 1e-9)
    return rate, verdicts


def main() -> dict:
    stages = build_pipeline()
    stream = traffic.make_stream("ddos_burst", n_packets=N_PACKETS, seed=1)

    eng_off = _make_engine(stages, telemetry=False)
    eng_on = _make_engine(stages, telemetry=None)   # full plane, default
    assert eng_off.telemetry() is None
    tel = eng_on.telemetry()
    assert tel is not None

    # one warm pass each, then the interleaved measurement rounds
    for _ in eng_off.serve_stream(stream.chunks(MAX_BATCH)):
        pass
    for _ in eng_on.serve_stream(stream.chunks(MAX_BATCH)):
        pass
    off_rates, on_rates, off_v, on_v = [], [], None, None
    for _ in range(ROUNDS):
        r, off_v = _one_round(eng_off, stream)
        off_rates.append(r)
        r, on_v = _one_round(eng_on, stream)
        on_rates.append(r)
    pair_ratios = [on / off for on, off in zip(on_rates, off_rates)]

    # observation must not perturb the data path: bit-identical verdicts
    np.testing.assert_array_equal(
        off_v, on_v, err_msg="telemetry changed the served verdicts")

    # the recorded counters must account for every packet served
    snap = tel.snapshot()
    counted = snap["serve_packets_total"]["values"][0]["value"]
    assert counted == eng_on.stats_.packets, (
        f"packet counter {counted} != packets served "
        f"{eng_on.stats_.packets}")

    best_off, best_on = max(off_rates), max(on_rates)
    ratio = max(pair_ratios)
    mean_ratio = float(np.mean(pair_ratios))
    rows = [
        {"mode": "telemetry off", "best_pps": round(best_off),
         "rounds_pps": [round(r) for r in off_rates]},
        {"mode": "telemetry on", "best_pps": round(best_on),
         "rounds_pps": [round(r) for r in on_rates]},
    ]
    print("\n== telemetry overhead (fused stateful pipeline, pkt/s) ==")
    print(render_table(rows, ["mode", "best_pps", "rounds_pps"]))
    print(f"pair ratios   {[round(r, 4) for r in pair_ratios]}")
    print(f"on/off ratio  best-pair {ratio:.4f}, mean {mean_ratio:.4f}  "
          f"(gate >= {TELEMETRY_OVERHEAD_GATE} on best pair)")

    s = eng_on.stats()
    payload = {
        "n_packets": N_PACKETS,
        "max_batch": MAX_BATCH,
        "rounds": ROUNDS,
        "backend": s["backend"],
        "pps_off_best": round(best_off, 1),
        "pps_on_best": round(best_on, 1),
        "pair_ratios": [round(r, 4) for r in pair_ratios],
        "overhead_ratio": round(ratio, 4),
        "overhead_ratio_mean": round(mean_ratio, 4),
        "gate": TELEMETRY_OVERHEAD_GATE,
        "verdicts_match": True,
        "metrics_recorded": sorted(snap),
        "spans_recorded": len(tel.tracer.spans()),
        "serve_stats": [{
            "engine": "PacketServeEngine",
            "pipeline": "flow-ddos+telemetry",
            "backend": s["backend"],
            "depth": s["depth"],
            "shards": s["shards"],
            "pkt_per_s": s["pkt_per_s"],
            "lat_p50_ms": s["lat_p50_ms"],
            "lat_p95_ms": s["lat_p95_ms"],
            "lat_p99_ms": s["lat_p99_ms"],
            "telemetry_overhead_ratio": round(ratio, 4),
        }],
    }
    save_result("telemetry_overhead", payload)

    # the gate LAST, after the artifact records the measured numbers
    assert ratio >= TELEMETRY_OVERHEAD_GATE, (
        f"telemetry overhead above budget: best paired on/off ratio "
        f"{ratio:.4f} < {TELEMETRY_OVERHEAD_GATE} (pairs "
        f"{[round(r, 3) for r in pair_ratios]})"
    )
    return payload


if __name__ == "__main__":
    main()
