"""Paper Table 2: hand-tuned baselines vs Homunculus-generated models.

Baselines follow the paper's descriptions:
  AD: the Taurus [85]/[86] hand-crafted DNN (~200 params: 7->12->8->2)
  TC: hand-written DNN with 3 hidden layers (10, 10, 5)   [§5 Baselines]
  BD: 4 hidden layers of 10 neurons on 30-bin flowmarkers [§5.1.2]

Homunculus searches the same platform (16x16 Taurus grid, 1 GPkt/s, 500 ns)
with the DNN algorithm space.  Datasets are seeded synthetic replicas
(data/netdata.py), so ABSOLUTE F1 differs from the paper; the CLAIM under
test is relative: generated >= hand-tuned, by exploiting the resource
headroom (more CU/MU used).
"""

from __future__ import annotations

import homunculus
from homunculus.alchemy import DataLoader, Model, Platforms
from repro.core import mlalgos
from repro.core.feasibility import TaurusModel
from repro.data import netdata

from benchmarks.common import Timer, render_table, save_result


def _taurus():
    p = Platforms.Taurus()
    p.constrain(performance={"throughput": 1, "latency": 500},
                resources={"rows": 16, "cols": 16})
    return p


def _baseline_row(app, data, hidden, seed=0):
    tm = mlalgos.train_dnn(data, hidden=hidden, epochs=12, seed=seed)
    f1 = mlalgos.f1_score(data.test_y, tm.predict(data.test_x),
                          num_classes=data.num_classes)
    est = TaurusModel().estimate("dnn", tm.topology)["options"][0]
    return {
        "application": f"Base-{app}", "features": data.num_features,
        "params": tm.param_count, "f1": round(f1, 4),
        "cu": est["cu"], "mu": est["mu"],
    }


def _homunculus_row(app, loader, *, budget, seed=0):
    model = Model({
        "optimization_metric": ["f1"],
        "algorithm": ["dnn"],
        "name": app,
        "data_loader": loader,
    })
    p = _taurus()
    p.schedule(model)
    res = homunculus.generate(p, budget=budget, n_init=6, seed=seed)
    r = res[app]
    data = loader()
    r.pipeline.verify(data.test_x)  # generated == trained, exactly
    return {
        "application": f"Hom-{app}", "features": data.num_features,
        "params": r.trained.param_count, "f1": round(r.value, 4),
        "cu": r.report.resources["cu"], "mu": r.report.resources["mu"],
    }, r


def main(budget: int = 14) -> dict:
    rows = []

    @DataLoader
    def ad_loader():
        return netdata.make_ad_dataset(features=7, n_train=4096, n_test=2048)

    @DataLoader
    def tc_loader():
        return netdata.make_tc_dataset(n_train=4096, n_test=2048)

    _bd_cache = {}

    @DataLoader
    def bd_loader():
        if "d" not in _bd_cache:
            _bd_cache["d"], _bd_cache["flows"] = netdata.make_bd_dataset(
                n_flows=2400
            )
        return _bd_cache["d"]

    with Timer() as t:
        rows.append(_baseline_row("AD", ad_loader(), [12, 8]))
        hom_ad, _ = _homunculus_row("AD", ad_loader, budget=budget)
        rows.append(hom_ad)

        rows.append(_baseline_row("TC", tc_loader(), [10, 10, 5]))
        hom_tc, _ = _homunculus_row("TC", tc_loader, budget=budget)
        rows.append(hom_tc)

        # BD per the paper §5.1.2: "training was done on full flow-level
        # histograms, while the F1 scores are reported on the
        # per-packet-level partial histograms"
        rows.append(_baseline_row("BD", bd_loader(), [10, 10, 10, 10]))
        hom_bd, r_bd = _homunculus_row("BD", bd_loader, budget=budget)
        rows.append(hom_bd)
        X10, y10 = netdata.bd_partial_eval_set(
            _bd_cache["flows"], checkpoints=(10,)
        )[10]
        base_bd = mlalgos.train_dnn(
            bd_loader(), hidden=[10, 10, 10, 10], epochs=12, seed=0
        )
        rows[-2]["f1"] = round(mlalgos.f1_score(
            y10, base_bd.predict(X10)
        ), 4)
        rows[-1]["f1"] = round(mlalgos.f1_score(
            y10, r_bd.pipeline(X10)
        ), 4)
        rows[-2]["application"] = "Base-BD(pp)"
        rows[-1]["application"] = "Hom-BD(pp)"

    cols = ["application", "features", "params", "f1", "cu", "mu"]
    print("\n== Table 2: baseline vs Homunculus (Taurus 16x16) ==")
    print(render_table(rows, cols))

    gains = {}
    for app in ("AD", "TC", "BD"):
        b = next(r for r in rows
                 if r["application"].startswith(f"Base-{app}"))["f1"]
        h = next(r for r in rows
                 if r["application"].startswith(f"Hom-{app}"))["f1"]
        gains[app] = round(h - b, 4)
    print(f"F1 gains (generated - hand-tuned): {gains}")
    payload = {"rows": rows, "gains": gains, "wall_s": round(t.wall_s, 1)}
    save_result("table2_f1", payload)
    return payload


if __name__ == "__main__":
    main()
