"""Deliverables (e)+(g): summarize the multi-pod dry-run artifacts into the
roofline table (reads benchmarks/results/dryrun/*.json written by
``python -m repro.launch.dryrun --all --mesh both``)."""

from __future__ import annotations

import os

from repro.launch.roofline import load_all, render_markdown

from benchmarks.common import save_result

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


OPT_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun_opt")


def _summarize(dirpath: str, label: str) -> dict:
    cells = load_all(dirpath)
    ok = [c for c in cells if c.ok]
    fail = [c for c in cells if not c.ok]
    print(f"\n== {label}: {len(cells)} cells ({len(ok)} ok, "
          f"{len(fail)} failed) ==")
    by_dom = {}
    for c in ok:
        by_dom[c.dominant] = by_dom.get(c.dominant, 0) + 1
    print(f"dominant terms: {by_dom}")
    for c in fail:
        print(f"  FAILED: {c.mesh} {c.arch} {c.shape}: {c.error[:100]}")
    return {
        "cells": len(cells), "ok": len(ok),
        "dominant_histogram": by_dom,
        "table_markdown": render_markdown(cells),
        "bounds": {
            f"{c.mesh}/{c.arch}/{c.shape}": round(c.t_bound, 4)
            for c in ok
        },
    }


def main() -> dict:
    if not os.path.isdir(DRYRUN_DIR) or not os.listdir(DRYRUN_DIR):
        print("no dry-run artifacts found; run "
              "`python -m repro.launch.dryrun --all --mesh both` first")
        return {"cells": 0}
    payload = {"baseline": _summarize(DRYRUN_DIR, "BASELINE (paper-faithful)")}
    if os.path.isdir(OPT_DIR) and os.listdir(OPT_DIR):
        payload["optimized"] = _summarize(OPT_DIR, "OPTIMIZED (§Perf passes)")
        base, opt = payload["baseline"]["bounds"], payload["optimized"]["bounds"]
        speedups = {
            k: round(base[k] / opt[k], 2)
            for k in base if k in opt and opt[k] > 0
        }
        top = sorted(speedups.items(), key=lambda kv: -kv[1])[:10]
        import statistics

        print("\nbound speedups (baseline/optimized), top 10:")
        for k, v in top:
            print(f"  {v:6.2f}x  {k}")
        print(f"median speedup across cells: "
              f"{statistics.median(speedups.values()):.2f}x")
        payload["speedups"] = speedups
    save_result("dryrun_roofline", payload)
    return payload


if __name__ == "__main__":
    main()
