"""Shared benchmark utilities: result table rendering, JSON persistence,
and the forced-multi-device subprocess probe the sharded serving rows use."""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# marker line a multi-device probe script prints its stats JSON behind
SHARDED_MARKER = "SHARDED-STATS "


def run_sharded_probe(script: str, *, n_devices: int = 4,
                      timeout: int = 900) -> dict:
    """Run ``script`` in a subprocess with ``n_devices`` forced host CPU
    devices (``--xla_force_host_platform_device_count``, the same trick as
    ``tests/test_sharded_engine.py``) so ``ShardedPacketServeEngine`` rows
    in BENCH_serve.json record REAL multi-device runs — the ``shards``
    field then carries the actual device count instead of the one-device
    degradation.  The script must print one line
    ``SHARDED-STATS {json}``; returns the parsed dict."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p
    )
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=timeout, env=env, cwd=REPO_ROOT,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded probe failed (rc={proc.returncode}):\n"
            f"{proc.stderr[-4000:]}"
        )
    for line in proc.stdout.splitlines():
        if line.startswith(SHARDED_MARKER):
            return json.loads(line[len(SHARDED_MARKER):])
    raise RuntimeError(
        f"sharded probe printed no {SHARDED_MARKER!r} line:\n"
        f"{proc.stdout[-2000:]}"
    )


def save_result(name: str, payload: dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


def render_table(rows: list[dict], columns: list[str]) -> str:
    widths = {
        c: max(len(c), *(len(str(r.get(c, ""))) for r in rows)) for c in columns
    }
    line = " | ".join(c.ljust(widths[c]) for c in columns)
    sep = "-+-".join("-" * widths[c] for c in columns)
    body = "\n".join(
        " | ".join(str(r.get(c, "")).ljust(widths[c]) for c in columns)
        for r in rows
    )
    return f"{line}\n{sep}\n{body}"


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.wall_s = time.perf_counter() - self.t0


def bench_pps(fn, X, repeats: int = 20) -> float:
    """Measured items/sec of ``fn(X)``: one warm-up call (compile), then
    ``repeats`` timed calls — the shared methodology of the pkt/s benches."""
    fn(X)
    with Timer() as t:
        for _ in range(repeats):
            fn(X)
    return repeats * len(X) / t.wall_s


def bench_pps_best(fn, X, rounds: int = 5, repeats: int = 20) -> float:
    """Best-of-``rounds`` ``bench_pps``: the A/B gates (pallas >= interp,
    fused-DAG >= per-model) compare best-case rates so scheduler noise on
    shared runners doesn't flip a structural speedup into a flake."""
    fn(X)
    return max(bench_pps(fn, X, repeats) for _ in range(rounds))
