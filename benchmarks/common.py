"""Shared benchmark utilities: result table rendering + JSON persistence."""

from __future__ import annotations

import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def save_result(name: str, payload: dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


def render_table(rows: list[dict], columns: list[str]) -> str:
    widths = {
        c: max(len(c), *(len(str(r.get(c, ""))) for r in rows)) for c in columns
    }
    line = " | ".join(c.ljust(widths[c]) for c in columns)
    sep = "-+-".join("-" * widths[c] for c in columns)
    body = "\n".join(
        " | ".join(str(r.get(c, "")).ljust(widths[c]) for c in columns)
        for r in rows
    )
    return f"{line}\n{sep}\n{body}"


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.wall_s = time.perf_counter() - self.t0


def bench_pps(fn, X, repeats: int = 20) -> float:
    """Measured items/sec of ``fn(X)``: one warm-up call (compile), then
    ``repeats`` timed calls — the shared methodology of the pkt/s benches."""
    fn(X)
    with Timer() as t:
        for _ in range(repeats):
            fn(X)
    return repeats * len(X) / t.wall_s


def bench_pps_best(fn, X, rounds: int = 5, repeats: int = 20) -> float:
    """Best-of-``rounds`` ``bench_pps``: the A/B gates (pallas >= interp,
    fused-DAG >= per-model) compare best-case rates so scheduler noise on
    shared runners doesn't flip a structural speedup into a flake."""
    fn(X)
    return max(bench_pps(fn, X, repeats) for _ in range(rounds))
