"""Paper Figure 7: KMeans traffic classification on MAT-based switches under
shrinking table budgets (K5..K1).  Homunculus conforms k to the available
MATs (1 MAT per cluster, IIsy rule), trading V-measure for resources."""

from __future__ import annotations

from homunculus.alchemy import DataLoader, Model, Platforms
from repro.core.dse import search_model
from repro.data import netdata

from benchmarks.common import Timer, render_table, save_result


def main(budget: int = 10) -> dict:
    @DataLoader
    def tc_loader():
        return netdata.make_tc_dataset(n_train=4096, n_test=2048)

    rows = []
    with Timer() as t:
        for tables in (5, 4, 3, 2, 1):
            m = Model({
                "optimization_metric": ["v_measure"],
                "algorithm": ["kmeans"],
                "name": f"tc_k{tables}",
                "data_loader": tc_loader,
            })
            p = Platforms.Tofino()
            p.constrain(performance={"throughput": 1},
                        resources={"tables": tables})
            res = search_model(p, m, budget=budget, n_init=4, seed=0)
            rows.append({
                "mats_available": tables,
                "k_chosen": res.trained.topology["k"],
                "v_measure": round(res.value, 4),
                "mats_used": res.report.resources["mats"],
            })

    print("\n== Figure 7: KMeans V-measure vs MAT budget (IIsy backend) ==")
    print(render_table(rows, list(rows[0])))
    # graceful degradation: V-measure non-increasing as tables shrink (approx)
    vs = [r["v_measure"] for r in rows]
    assert vs[0] >= vs[-1], vs
    for r in rows:
        assert r["mats_used"] <= r["mats_available"]
    payload = {"rows": rows, "wall_s": round(t.wall_s, 1)}
    save_result("fig7_kmeans_mats", payload)
    return payload


if __name__ == "__main__":
    main()
