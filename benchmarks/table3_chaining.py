"""Paper Table 3: resource scaling under app-chaining strategies.

Chained copies of the AD DNN in sequential / parallel / mixed DAGs; the
resource count must stay constant with the number of copies and across
strategies (shared weights + negligible glue)."""

from __future__ import annotations

import homunculus
from homunculus.alchemy import DataLoader, Model, Platforms
from repro.core import chaining
from repro.data import netdata

from benchmarks.common import Timer, render_table, save_result


def main(budget: int = 8) -> dict:
    @DataLoader
    def ad_loader():
        return netdata.make_ad_dataset(features=7, n_train=2048, n_test=1024)

    m = Model({
        "optimization_metric": ["f1"], "algorithm": ["dnn"],
        "name": "ad", "data_loader": ad_loader,
    })
    p = Platforms.Taurus()
    p.constrain(performance={"throughput": 1, "latency": 500},
                resources={"rows": 16, "cols": 16})
    p.schedule(m)
    with Timer() as t:
        res = homunculus.generate(p, budget=budget, n_init=4, seed=0)
        from repro.core.alchemy import NATURAL_CHAINS_OK

        if NATURAL_CHAINS_OK:
            seq4 = m > m > m > m
            mixed = m > (m | m) > m
        else:  # interpreter defeats chained-comparison interception
            seq4 = ((m > m) > m) > m
            mixed = (m > (m | m)) > m
        strategies = {
            "DNN > DNN > DNN > DNN": seq4,
            "DNN | DNN | DNN | DNN": m | m | m | m,
            "DNN > (DNN | DNN) > DNN": mixed,
        }
        rows = chaining.strategy_table(strategies, res)

    print("\n== Table 3: resource scaling across chaining strategies ==")
    print(render_table(rows, ["strategy", "cu", "mu", "latency_ns"]))
    cus = {r["cu"] for r in rows}
    assert len(cus) == 1, f"resources vary across strategies: {rows}"
    single = res["ad"].report.resources["cu"]
    print(f"single-model CU = {single}; 4-copy chains use the same "
          f"(weights + pipeline logic shared; glue fits existing CUs)")
    payload = {"rows": rows, "single_cu": single, "wall_s": round(t.wall_s, 1)}
    save_result("table3_chaining", payload)
    return payload


if __name__ == "__main__":
    main()
