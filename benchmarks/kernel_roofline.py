"""Fused-MLP kernel roofline (the TPU per-packet pipeline, beyond-paper
backend): analytic packets/s vs depth on the v5e target + interpret-mode
correctness spot-check on CPU + measured interpreter-vs-Pallas serving
throughput for the same topologies (the two engines of
``stageir.compile_stages``), plus the STATEFUL step — the fused
single-launch flow pipeline vs the scan interpreter
(docs/pipeline_ir.md#flow-state-contract)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import stageir
from repro.core.feasibility import TPUModel
from repro.core.stageir import FusedMLP, Reduce
from repro.kernels.fused_mlp import fused_mlp, vmem_bytes
from repro.kernels.fused_mlp.ref import mlp_ref

from benchmarks.common import Timer, bench_pps, render_table, save_result

MEASURE_BATCH = 4096
MEASURE_REPEATS = 10

STATEFUL_BATCHES = (256, 512)
STATEFUL_REPEATS = 20


def _stateful_suffixes(rng, ws_out) -> dict[str, list]:
    """One classifier suffix per fused-envelope kind: the dense MLP head,
    the range-table (MAT) form, a centroid table, and the MLP head with
    the in-kernel mitigation fold."""
    from repro.core.stageir import (
        CentroidDistance, LUTGather, Mitigate, Quantize,
    )
    from repro.flowstate import MitigationSpec

    W = [np.asarray(rng.normal(size=(ws_out, 16)) * 0.2, np.float32),
         np.asarray(rng.normal(size=(16, 2)) * 0.2, np.float32)]
    B = [np.zeros(16, np.float32), np.zeros(2, np.float32)]
    mlp = [FusedMLP(W, B), Reduce("argmax")]
    edges = np.sort(rng.random((ws_out, 7)).astype(np.float32), axis=1)
    tables = rng.random((ws_out, 8, 2)).astype(np.float32)
    cent = np.asarray(rng.normal(size=(4, ws_out)), np.float32)
    return {
        "mlp": mlp,
        "mat": [Quantize(edges), LUTGather(tables), Reduce("argmax")],
        "centroid": [CentroidDistance(cent), Reduce("argmin")],
        "mlp+mitigate": mlp + [Mitigate(MitigationSpec(n_slots=2048,
                                                       threshold=4))],
    }


def stateful_rows(rng) -> list[dict]:
    """interp-vs-pallas columns for the STATEFUL step: the canonical
    flow-feature prefix + one head per fused-envelope suffix kind (MLP,
    MAT, centroid, MLP + in-kernel mitigation), measured as raw chained
    ``pipe(state, X)`` steps (state threads batch to batch, so the
    sequential dependency is part of the measured rate)."""
    from repro.data import traffic
    from repro.flowstate import StatefulPipeline

    (fk, ru, ws), names = traffic.flow_feature_stages(n_slots=2048)
    rows = []
    for sfx_name, suffix in _stateful_suffixes(rng, ws.n_out).items():
        stages = [fk, ru, ws] + suffix
        pipes = {b: StatefulPipeline(stages, backend=b)
                 for b in ("interpret", "pallas")}
        assert pipes["pallas"].backend == "pallas-fused-flow", (
            sfx_name, pipes["pallas"].backend,
            pipes["pallas"].fallback_reason,
        )
        # the MLP head sweeps every batch size; the widened-envelope
        # suffixes add one row each at the largest batch
        batches = (STATEFUL_BATCHES if sfx_name == "mlp"
                   else STATEFUL_BATCHES[-1:])
        for batch in batches:
            stream = traffic.make_stream("ddos_burst", n_packets=batch * 8,
                                         seed=2)
            X = np.stack(list(stream.chunks(batch)))    # [8, batch, F]
            rates = {}
            for name, pipe in pipes.items():
                def run_stream(chunks, _p=pipe):
                    state = _p.init_state()
                    for c in chunks:
                        state, v = _p(state, c)
                    return v
                rates[name] = bench_pps(
                    lambda xs: run_stream(xs), list(X),
                    STATEFUL_REPEATS
                ) * batch       # bench_pps counts chunks; scale to packets
            rows.append({
                "suffix": sfx_name,
                "batch": batch,
                "interp_kpkt_s": round(rates["interpret"] / 1e3, 1),
                "pallas_kpkt_s": round(rates["pallas"] / 1e3, 1),
                "speedup": round(rates["pallas"] / rates["interpret"], 2),
                "pallas_backend": pipes["pallas"].backend,
            })
    return rows


def main() -> dict:
    tpu = TPUModel()
    rows = []
    rng = np.random.default_rng(0)
    with Timer() as t:
        for depth in (1, 2, 4, 8, 10):
            widths = [32] + [64] * (depth - 1) + [2]
            est = tpu.estimate("dnn", {"widths": widths})
            # interpret-mode correctness for this exact topology
            ws = [jnp.asarray(rng.normal(size=(widths[i], widths[i + 1])) * 0.2,
                              jnp.float32) for i in range(len(widths) - 1)]
            bs = [jnp.zeros((widths[i + 1],), jnp.float32)
                  for i in range(len(widths) - 1)]
            x = jnp.asarray(rng.normal(size=(64, widths[0])), jnp.float32)
            err = float(jnp.max(jnp.abs(
                fused_mlp(x, ws, bs) - mlp_ref(x, ws, bs)
            )))
            # measured serving throughput: interpreter vs Pallas backend
            stages = [FusedMLP([np.asarray(w) for w in ws],
                               [np.asarray(b) for b in bs]),
                      Reduce("argmax")]
            run_i = stageir.compile_stages(stages, backend="interpret")
            run_p = stageir.compile_stages(stages, backend="pallas")
            X = jnp.asarray(
                rng.normal(size=(MEASURE_BATCH, widths[0])), jnp.float32
            )
            np.testing.assert_array_equal(np.asarray(run_i(X)),
                                          np.asarray(run_p(X)))
            interp_pps = bench_pps(
                lambda x: np.asarray(run_i(x)), X, MEASURE_REPEATS
            )
            pallas_pps = bench_pps(
                lambda x: np.asarray(run_p(x)), X, MEASURE_REPEATS
            )
            rows.append({
                "layers": depth,
                "vmem_KiB": vmem_bytes(depth) // 1024,
                "roofline_gpkt_s": round(est["throughput_pps"] / 1e9, 3),
                "latency_us": round(est["latency_ns"] / 1e3, 2),
                "interp_mpkt_s": round(interp_pps / 1e6, 2),
                "pallas_mpkt_s": round(pallas_pps / 1e6, 2),
                "pallas_backend": run_p.backend,
                "interpret_err": f"{err:.1e}",
            })

    print("\n== fused_mlp kernel: VMEM + roofline + measured serving ==")
    print(render_table(rows, list(rows[0])))
    for r in rows:
        assert float(r["interpret_err"]) < 1e-3
        assert r["pallas_backend"] == "pallas"

    srows = stateful_rows(rng)
    print("\n== stateful flow step: interpreter vs fused Pallas launch ==")
    print(render_table(srows, list(srows[0])))

    payload = {"rows": rows, "stateful_rows": srows,
               "wall_s": round(t.wall_s, 1)}
    save_result("kernel_roofline", payload)
    return payload


if __name__ == "__main__":
    main()
