"""Fused-MLP kernel roofline (the TPU per-packet pipeline, beyond-paper
backend): analytic packets/s vs depth on the v5e target + interpret-mode
correctness spot-check on CPU + measured interpreter-vs-Pallas serving
throughput for the same topologies (the two engines of
``stageir.compile_stages``)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import stageir
from repro.core.feasibility import TPUModel
from repro.core.stageir import FusedMLP, Reduce
from repro.kernels.fused_mlp import fused_mlp, vmem_bytes
from repro.kernels.fused_mlp.ref import mlp_ref

from benchmarks.common import Timer, bench_pps, render_table, save_result

MEASURE_BATCH = 4096
MEASURE_REPEATS = 10


def main() -> dict:
    tpu = TPUModel()
    rows = []
    rng = np.random.default_rng(0)
    with Timer() as t:
        for depth in (1, 2, 4, 8, 10):
            widths = [32] + [64] * (depth - 1) + [2]
            est = tpu.estimate("dnn", {"widths": widths})
            # interpret-mode correctness for this exact topology
            ws = [jnp.asarray(rng.normal(size=(widths[i], widths[i + 1])) * 0.2,
                              jnp.float32) for i in range(len(widths) - 1)]
            bs = [jnp.zeros((widths[i + 1],), jnp.float32)
                  for i in range(len(widths) - 1)]
            x = jnp.asarray(rng.normal(size=(64, widths[0])), jnp.float32)
            err = float(jnp.max(jnp.abs(
                fused_mlp(x, ws, bs) - mlp_ref(x, ws, bs)
            )))
            # measured serving throughput: interpreter vs Pallas backend
            stages = [FusedMLP([np.asarray(w) for w in ws],
                               [np.asarray(b) for b in bs]),
                      Reduce("argmax")]
            run_i = stageir.compile_stages(stages, backend="interpret")
            run_p = stageir.compile_stages(stages, backend="pallas")
            X = jnp.asarray(
                rng.normal(size=(MEASURE_BATCH, widths[0])), jnp.float32
            )
            np.testing.assert_array_equal(np.asarray(run_i(X)),
                                          np.asarray(run_p(X)))
            interp_pps = bench_pps(
                lambda x: np.asarray(run_i(x)), X, MEASURE_REPEATS
            )
            pallas_pps = bench_pps(
                lambda x: np.asarray(run_p(x)), X, MEASURE_REPEATS
            )
            rows.append({
                "layers": depth,
                "vmem_KiB": vmem_bytes(depth) // 1024,
                "roofline_gpkt_s": round(est["throughput_pps"] / 1e9, 3),
                "latency_us": round(est["latency_ns"] / 1e3, 2),
                "interp_mpkt_s": round(interp_pps / 1e6, 2),
                "pallas_mpkt_s": round(pallas_pps / 1e6, 2),
                "pallas_backend": run_p.backend,
                "interpret_err": f"{err:.1e}",
            })

    print("\n== fused_mlp kernel: VMEM + roofline + measured serving ==")
    print(render_table(rows, list(rows[0])))
    for r in rows:
        assert float(r["interpret_err"]) < 1e-3
        assert r["pallas_backend"] == "pallas"
    payload = {"rows": rows, "wall_s": round(t.wall_s, 1)}
    save_result("kernel_roofline", payload)
    return payload


if __name__ == "__main__":
    main()
