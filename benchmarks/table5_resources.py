"""Paper Table 5: FPGA testbed resource/power table.

Real power draw is unmeasurable here; the FPGA resource model reports
LUT/FF/BRAM% of an Alveo U250 for the same six models (base/hom x AD/TC/BD)
plus the loopback shell, and an energy *proxy* (pJ-scale: bytes moved +
flops at published per-op energies) replaces the watts column, as stated in
DESIGN.md §8."""

from __future__ import annotations

from repro.core import mlalgos
from repro.core.feasibility import FPGAModel
from repro.data import netdata

from benchmarks.common import Timer, render_table, save_result

# energy proxies (45nm-class, Horowitz ISSCC'14 scale)
PJ_PER_FLOP = 1.2
PJ_PER_BYTE = 6.0


def _row(name, model, fpga):
    est = fpga.estimate("dnn", model.topology)
    params = model.param_count
    flops = 2 * params
    nbytes = 4 * params
    energy_nj = (flops * PJ_PER_FLOP + nbytes * PJ_PER_BYTE) / 1e3
    return {
        "application": name, "model": "DNN",
        "lut_pct": round(100 * est["luts"] / fpga.total_luts + 5.36, 2),
        "ff_pct": round(100 * est["ffs"] / fpga.total_ffs + 3.64, 2),
        "bram_pct": 4.15,
        "energy_nj_per_pkt": round(energy_nj, 2),
    }


def main() -> dict:
    fpga = FPGAModel()
    with Timer() as t:
        ad = netdata.make_ad_dataset(features=7, n_train=2048, n_test=1024)
        tc = netdata.make_tc_dataset(n_train=2048, n_test=1024)
        bd, _ = netdata.make_bd_dataset(n_flows=1200)

        rows = [{
            "application": "Loopback", "model": "-", "lut_pct": 5.36,
            "ff_pct": 3.64, "bram_pct": 4.15, "energy_nj_per_pkt": 0.0,
        }]
        specs = [
            ("Base-AD", ad, [12, 8]), ("Hom-AD", ad, [24, 16, 8]),
            ("Base-TC", tc, [10, 10, 5]), ("Hom-TC", tc, [32, 16]),
            ("Base-BD", bd, [10, 10, 10, 10]), ("Hom-BD", bd, [16, 12, 8, 8, 6]),
        ]
        for name, data, hidden in specs:
            m = mlalgos.train_dnn(data, hidden=hidden, epochs=6, seed=0)
            rows.append(_row(name, m, fpga))

    print("\n== Table 5: FPGA resource utilization (Alveo U250 model) ==")
    print(render_table(rows, list(rows[0])))
    # bigger Hom models -> more LUTs/FFs than their baselines (paper's trend)
    lut = {r["application"]: r["lut_pct"] for r in rows}
    assert lut["Hom-AD"] > lut["Base-AD"]
    assert lut["Hom-TC"] > lut["Base-TC"]
    payload = {"rows": rows, "wall_s": round(t.wall_s, 1)}
    save_result("table5_resources", payload)
    return payload


if __name__ == "__main__":
    main()
