"""Multi-application data plane: AD gating TC, plus model fusion (§3.2.5).

Schedules anomaly-detection in FRONT of traffic classification on one Taurus
switch (packets flagged malicious skip classification), then demonstrates
fusing two models trained on overlapping feature sets.

  PYTHONPATH=src python examples/multi_app_chaining.py
"""

import numpy as np

import homunculus
from homunculus.alchemy import DataLoader, Model, Platforms
from repro.core import chaining, fusion
from repro.data import netdata


@DataLoader
def ad_loader():
    return netdata.make_ad_dataset(features=7, n_train=2048, n_test=1024)


@DataLoader
def tc_loader():
    return netdata.make_tc_dataset(n_train=2048, n_test=1024)


ad = Model({"optimization_metric": ["f1"], "algorithm": ["dnn"],
            "name": "ad", "data_loader": ad_loader})
tc = Model({"optimization_metric": ["f1"], "algorithm": ["dnn"],
            "name": "tc", "data_loader": tc_loader})

platform = Platforms.Taurus()
platform.constrain(performance={"throughput": 1, "latency": 500},
                   resources={"rows": 16, "cols": 16})
platform.schedule(ad > tc)  # sequential: AD verdict gates TC

res = homunculus.generate(platform, budget=10, n_init=5, seed=0)
print("\nschedule:", res.schedule)
for name in ("ad", "tc"):
    print(f"  {name}: {res[name].summary()}")
print("combined DAG resources:", res.dag_report.resources,
      f"(fits 16x16 grid: {res.dag_report.resources['cu'] <= 256})")

# run packets through the chain — whole DAG compiled into ONE jitted
# program (AD gate as jnp.where masking), vs the eager per-stage path
X = ad_loader().test_x[:512]
dag = chaining.compile_dag(platform.scheduled, res)
verdict = dag(X)
eager = chaining.run_dag(platform.scheduled, res, X)
assert np.array_equal(verdict, eager)
print(f"\nAD gate: {np.mean(np.asarray(res['ad'].pipeline(X)) == 1):.1%} "
      f"of packets flagged; flagged packets short-circuit TC")
print(f"compiled DAG == eager DAG on {len(X)} packets: "
      f"{np.array_equal(verdict, eager)}")

# serve the compiled DAG through the micro-batching packet engine
from repro.serve.packet_engine import PacketServeEngine

eng = PacketServeEngine(dag, feature_dim=X.shape[1], max_batch=256)
eng.submit(X)
eng.flush()
print("packet engine:", eng.stats())

# ---- fusion: two models on split halves of the same feature space
part1, part2 = ad_loader().split_half()
print(f"\nfeature overlap part1/part2: "
      f"{fusion.feature_overlap(part1, part2):.2f} "
      f"-> fuse: {fusion.should_fuse(part1, part2)}")
fused = fusion.fuse([part1, part2], hidden=[24, 16], epochs=8)
print(f"fused model: {fused.param_count} params, "
      f"F1 task0={fused.f1(0):.3f} task1={fused.f1(1):.3f} "
      f"(two tasks, one trunk)")
