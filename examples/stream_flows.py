"""Streaming botnet/DDoS detection on live per-flow state (paper §5.1.1).

examples/botnet_pipeline.py evaluates per-packet reaction time on
PRECOMPUTED partial histograms; this example closes the loop: a synthetic
DDoS-burst packet stream (repro/data/traffic.py) flows through a STATEFUL
pipeline — ``FlowKey -> RegisterUpdate`` maintains per-flow counters,
EWMAs and windowed histograms in a fixed-slot register file, and a DNN
classifies every packet on its flow's live register row — on both
execution engines (jitted reference vs ONE fused Pallas launch covering
registers AND classifier, ``pallas-fused-flow``, bit-identical
verdicts), reporting the per-part backend, pkt/s, per-batch latency
percentiles and reaction-time percentiles (packets until a flow's first
correct verdict).

  PYTHONPATH=src python examples/stream_flows.py
"""

import numpy as np

from repro.core import codegen, feasibility as feas, mlalgos
from repro.data import traffic
from repro.flowstate import StatefulPipeline
from repro.serve.packet_engine import PacketServeEngine

N_SLOTS = 2048
N_PACKETS = 16_000

# -- 1. train a per-packet flow classifier on a seeded DDoS-burst stream
train_stream = traffic.make_stream("ddos_burst", n_packets=N_PACKETS,
                                   seed=0)
stages, names = traffic.flow_feature_stages(n_slots=N_SLOTS)
ds, mu, sd = traffic.stream_feature_dataset(train_stream, stages, names,
                                            sample_every=2)
dnn = mlalgos.train_dnn(ds, hidden=[16, 8], epochs=3, seed=0)
f1 = mlalgos.f1_score(ds.test_y, dnn.predict(ds.test_x))
print(f"flow classifier: DNN {dnn.topology['widths']} "
      f"on {len(names)} register features, held-out F1 {f1:.4f}")

# feasibility: the register file co-resides with the model on the target
# (FeasibilityReport.merge — resources add, throughput is the min)
spec = stages[1].spec
print(f"register file: {spec.n_slots} slots x {spec.width} words "
      f"({spec.sram_bytes / 1024:.0f} KiB)")
for plat in ("taurus", "tpu"):
    rep = feas.flowstate_report(spec, plat)
    verdict = "fits" if rep.feasible else f"INFEASIBLE ({rep.reasons[0]})"
    print(f"  {plat:6s} {verdict}: {rep.resources}")

# -- 2. assemble the stateful pipeline: registers + classifier, with the
# training-time standardization folded into the first dense layer so the
# served pipeline consumes RAW register rows
suffix = traffic.fold_input_standardization(codegen.taurus_stages(dnn),
                                            mu, sd)
pipeline_stages = list(stages) + suffix

# -- 3. replay a fresh (unseen seed) stream through both engines
eval_stream = traffic.make_stream("ddos_burst", n_packets=N_PACKETS,
                                  seed=1)
verdicts = {}
for backend in ("interpret", "pallas"):
    pipe = StatefulPipeline(pipeline_stages, backend=backend)
    eng = PacketServeEngine(pipe, feature_dim=len(traffic.COLUMNS),
                            max_batch=512)
    got = [v for v in eng.serve_stream(eval_stream.chunks(512))]
    verdicts[backend] = np.concatenate(got)
    s = eng.stats()
    print(f"\n[{s['backend']}] {pipe!r}")
    # per-part backend report: which engine serves each half of the
    # pipeline — or ONE fused launch covering both (pallas-fused-flow)
    part = ("fused single launch" if pipe.fused
            else f"flow={pipe.flow_backend}  "
                 f"classifier={pipe.classifier_backend}")
    print(f"  parts: {part}")
    print(f"  {s['packets']} packets, {s['pkt_per_s']:,.0f} pkt/s, "
          f"{s['batches']} batches, {s['pad_packets']} pad rows")
    print(f"  per-batch latency: p50 {s['lat_p50_ms']:.3f} ms, "
          f"p95 {s['lat_p95_ms']:.3f} ms, p99 {s['lat_p99_ms']:.3f} ms")

assert pipe.backend == "pallas-fused-flow", pipe.backend

assert np.array_equal(verdicts["interpret"], verdicts["pallas"]), \
    "the two engines must produce bit-identical verdicts (same registers)"

# -- 4. reaction time: packets until each attack flow's first detection
rep = traffic.reaction_report(eval_stream, verdicts["pallas"])
print(f"\nreaction time on the DDoS burst ({rep['attack_flows']} attack "
      f"flows among {eval_stream.n_flows}):")
print(f"  detection rate        {rep['detection_rate']:.1%}")
print(f"  packets-to-detection  median {rep['reaction_pkts_median']:.0f}, "
      f"p95 {rep['reaction_pkts_p95']:.0f}")
print(f"  benign flows flagged  {rep['benign_fp_flow_rate']:.1%}")
print("\nFlowLens-style detectors wait for the full flow; this pipeline "
      "reacts within packets on live register state.")
