"""Botnet detection with per-packet reaction time (paper §5.1.1).

FlowLens detects botnets from FULL-flow histograms accumulated over up to
3600 s.  Homunculus searches a per-packet model on 30-bin flowmarkers and
classifies PARTIAL histograms as packets arrive — detection within tens of
packets instead of an hour.

  PYTHONPATH=src python examples/botnet_pipeline.py
"""

import numpy as np

import homunculus
from homunculus.alchemy import DataLoader, Model, Platforms
from repro.core import mlalgos
from repro.data import netdata

_cache = {}


@DataLoader
def bd_loader():
    if "d" not in _cache:
        _cache["d"], _cache["flows"] = netdata.make_bd_dataset(n_flows=2400)
    return _cache["d"]


model = Model({
    "optimization_metric": ["f1"],
    "algorithm": ["dnn"],
    "name": "botnet_detection",
    "data_loader": bd_loader,
})
platform = Platforms.Taurus()
platform.constrain(performance={"throughput": 1, "latency": 500},
                   resources={"rows": 16, "cols": 16})
platform.schedule(model)

res = homunculus.generate(platform, budget=12, n_init=6, seed=0)
r = res["botnet_detection"]
print("generated:", r.summary())

# per-packet partial-histogram evaluation on held-out flows
flows = _cache["flows"]
checkpoints = (2, 5, 10, 20, 40, 80)
partial = netdata.bd_partial_eval_set(flows, checkpoints)
f1_full = r.value
print(f"\nflow-level F1 (full flowmarkers): {f1_full:.4f}")
print("per-packet reaction curve:")
for k in checkpoints:
    X, y = partial[k]
    pred = r.pipeline(X)
    f1 = mlalgos.f1_score(y, pred)
    bar = "#" * int(40 * f1 / max(f1_full, 1e-9))
    print(f"  after {k:3d} packets: F1 {f1:.4f} {bar}")

print("\nreaction time: FlowLens waits up to 3600 s per flow; this pipeline "
      "classifies every packet at line rate with partial histograms.")
