"""End-to-end LM training driver: a ~100M-param decoder trained for a few
hundred steps on the synthetic Markov token stream, with async checkpoints,
crash-resume, and the straggler watchdog active.

  PYTHONPATH=src python examples/train_lm.py              # ~100M, 200 steps
  PYTHONPATH=src python examples/train_lm.py --tiny       # CI-sized
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data.tokens import TokenDataset
from repro.ft.restart import RestartManager
from repro.train.step import TrainSettings, init_train_state, make_train_step


def build_config(tiny: bool):
    base = get_smoke_config("qwen3-1.7b")
    if tiny:
        return base
    # ~110M params: 12L x d768 x ff3072, vocab 16384
    return dataclasses.replace(
        base, name="lm-100m", num_layers=12, d_model=768, num_heads=12,
        num_kv_heads=6, head_dim=64, d_ff=3072, vocab_size=16384,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = build_config(args.tiny)
    from repro.models import registry

    n_params = registry.param_count(cfg)
    print(f"training {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")

    data = TokenDataset(cfg.vocab_size, args.seq, args.batch, seed=0)
    settings = TrainSettings(
        peak_lr=1e-2, warmup=20, total_steps=args.steps, remat=True,
    )
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, settings), donate_argnums=(0,))

    mgr = RestartManager(args.ckpt_dir, save_every=50)
    mgr.watchdog.on_straggler = lambda s, r: print(
        f"  [watchdog] step {s} was {r:.1f}x median — would trigger "
        f"microbatch rebalance on a real pod"
    )
    state, start = mgr.maybe_restore(state)
    if start:
        print(f"resumed from checkpoint at step {start}")

    losses = []

    def cb(step, metrics, dt):
        losses.append(float(metrics["loss"]))
        if step % 20 == 0 or step == args.steps:
            print(f"step {step:4d}  loss {losses[-1]:.4f}  "
                  f"acc {float(metrics['accuracy']):.3f}  {dt * 1e3:.0f} ms")

    t0 = time.perf_counter()
    state, _ = mgr.run(
        state, step_fn, lambda s: {
            k: jnp.asarray(v) for k, v in data.batch_at(s).items()
        },
        num_steps=args.steps, start_step=start, metrics_cb=cb,
    )
    if losses:
        print(f"done in {time.perf_counter() - t0:.0f}s; "
              f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
              f"(unigram floor ~ {jnp.log(cfg.vocab_size):.2f})")
    else:
        print(f"nothing to do: checkpoint already at/after step {args.steps}")


if __name__ == "__main__":
    main()
