"""Serve packets through a generated pipeline — the data plane in action.

Generates the AD pipeline (fused-MLP Pallas artifact), then streams batched
"packets" through it, reporting CPU wall throughput and the projected TPU
roofline throughput the feasibility oracle promised.

  PYTHONPATH=src python examples/serve_packets.py
"""

import time

import numpy as np

import homunculus
from homunculus.alchemy import DataLoader, Model, Platforms
from repro.data import netdata


@DataLoader
def ad_loader():
    return netdata.make_ad_dataset(features=7, n_train=4096, n_test=2048)


model = Model({
    "optimization_metric": ["f1"],
    "algorithm": ["dnn"],
    "name": "ad",
    "data_loader": ad_loader,
})

# TPU backend: the beyond-paper target — same Alchemy program, new platform
platform = Platforms.TPU()
platform.constrain(performance={"throughput": 0.01, "latency": 1e6},
                   resources={"batch": 256})
platform.schedule(model)
res = homunculus.generate(platform, budget=10, n_init=5, seed=0)
r = res["ad"]
print("generated:", r.summary())

data = ad_loader()
pipe = r.pipeline

# stream packets in batches (CPU interpret mode; TPU runs the same kernel)
n_packets = 0
t0 = time.perf_counter()
malicious = 0
for start in range(0, len(data.test_x), 256):
    batch = data.test_x[start:start + 256]
    verdicts = pipe(batch)
    malicious += int(np.sum(verdicts == 1))
    n_packets += len(batch)
wall = time.perf_counter() - t0

print(f"\nstreamed {n_packets} packets in {wall:.2f}s "
      f"({n_packets / wall:,.0f} pkt/s on CPU interpret mode)")
print(f"flagged malicious: {malicious} ({malicious / n_packets:.1%})")
print(f"TPU roofline projection (oracle): "
      f"{r.report.throughput_pps:,.0f} pkt/s, "
      f"latency {r.report.latency_ns / 1e3:.1f} us/batch")
