"""Serve packets through a generated pipeline — the data plane in action.

Generates the AD pipeline (fused-MLP Pallas artifact), then streams batched
"packets" through it on BOTH execution engines — the jitted stage
interpreter and the Pallas backend (whole pipeline as one fused kernel
launch, docs/pipeline_ir.md#pallas-lowering-contract) — reporting CPU wall
throughput per engine and the projected TPU roofline throughput the
feasibility oracle promised.

  PYTHONPATH=src python examples/serve_packets.py
"""

import time

import numpy as np

import homunculus
from homunculus.alchemy import DataLoader, Model, Platforms
from repro.data import netdata


@DataLoader
def ad_loader():
    return netdata.make_ad_dataset(features=7, n_train=4096, n_test=2048)


model = Model({
    "optimization_metric": ["f1"],
    "algorithm": ["dnn"],
    "name": "ad",
    "data_loader": ad_loader,
})

# TPU backend: the beyond-paper target — same Alchemy program, new platform
platform = Platforms.TPU()
platform.constrain(performance={"throughput": 0.01, "latency": 1e6},
                   resources={"batch": 256})
platform.schedule(model)
res = homunculus.generate(platform, budget=10, n_init=5, seed=0)
r = res["ad"]
print("generated:", r.summary())

data = ad_loader()
pipe = r.pipeline
print("stage list:", [s.kind for s in pipe.stages])

# stream packets through the micro-batching engine on both execution
# engines: fixed batch shape -> compiled once per engine
from repro.serve.packet_engine import PacketServeEngine

verdict_sets = {}
for backend in ("interpret", "pallas"):
    eng = PacketServeEngine(pipe, feature_dim=data.num_features,
                            max_batch=256, backend=backend)
    t0 = time.perf_counter()
    malicious = 0
    chunks = (data.test_x[s:s + 97] for s in range(0, len(data.test_x), 97))
    got = []
    for verdicts in eng.serve_stream(chunks):
        malicious += int(np.sum(verdicts == 1))
        got.append(verdicts)
    wall = time.perf_counter() - t0
    stats = eng.stats()
    n_packets = stats["packets"]
    verdict_sets[backend] = np.concatenate(got)

    print(f"\n[{stats['backend']}] streamed {n_packets} packets in "
          f"{wall:.2f}s ({stats['pkt_per_s']:,.0f} pkt/s pipeline-only, "
          f"{stats['batches']} micro-batches, {stats['pad_packets']} pad rows, "
          f"depth {stats['depth']})")
    print(f"per-batch latency: p50 {stats['lat_p50_ms']:.3f} ms, "
          f"p95 {stats['lat_p95_ms']:.3f} ms, p99 {stats['lat_p99_ms']:.3f} ms"
          f" (host dispatch {stats['dispatch_s'] * 1e3:.1f} ms total)")
    print(f"flagged malicious: {malicious} ({malicious / n_packets:.1%})")

assert np.array_equal(verdict_sets["interpret"], verdict_sets["pallas"]), \
    "the two execution engines must agree bit-for-bit on dense pipelines"
print(f"TPU roofline projection (oracle): "
      f"{r.report.throughput_pps:,.0f} pkt/s, "
      f"latency {r.report.latency_ns / 1e3:.1f} us/batch")
