"""Closed-loop attack defense: detect, then DROP, inside the pipeline.

Detection alone is half a data-plane ML pipeline; this example closes
the loop.  A SYN-flood scenario trains a per-packet detector on live
per-flow registers, a ``Mitigate`` stage caps the pipeline with a
per-flow ACTION TABLE (same FNV flow key, [hits, since] rows), and a
fresh seed of the attack replays through ``PacketServeEngine``: once a
flow accumulates ``threshold`` positive verdicts its packets are dropped
at line rate — the verdict stream carries the ``MITIGATED`` sentinel and
no packet is ever both dropped and verdicted
([mitigation contract](../docs/pipeline_ir.md#mitigation-contract)).

The replay also shows the scenario suite's topology tools: the stream
split into per-switch views (flows pinned whole to their ingress
switch), and windowed flow stats auto-labeled by the heuristic rules a
controller would use.

  PYTHONPATH=src python examples/attack_defense.py
"""

import numpy as np

from repro.core import codegen, feasibility as feas, mlalgos, stageir
from repro.data import traffic
from repro.flowstate import MITIGATED, MitigationSpec, StatefulPipeline
from repro.serve.packet_engine import PacketServeEngine

N_PACKETS = 8_000
N_SLOTS = 1024
THRESHOLD = 8

# -- 1. train the detector on one seeded SYN-flood stream
train = traffic.make_stream("syn_flood", n_packets=N_PACKETS, seed=0)
stages, names = traffic.flow_feature_stages(n_slots=N_SLOTS)
ds, mu, sd = traffic.stream_feature_dataset(train, stages, names,
                                            sample_every=4)
dnn = mlalgos.train_dnn(ds, hidden=[16, 8], epochs=3, seed=0)
print(f"detector: DNN {dnn.topology['widths']} on {len(names)} register "
      f"features, held-out F1 "
      f"{mlalgos.f1_score(ds.test_y, dnn.predict(ds.test_x)):.4f}")

# -- 2. cap the pipeline with the action table; both register files are
# charged against the target's SRAM (FeasibilityReport.merge)
mit_spec = MitigationSpec(n_slots=4096, mode="drop", threshold=THRESHOLD)
suffix = traffic.fold_input_standardization(codegen.taurus_stages(dnn),
                                            mu, sd)
pipeline = list(stages) + suffix + [stageir.Mitigate(mit_spec)]
merged = feas.flowstate_report(stages[1].spec, "tofino").merge(
    feas.mitigation_report(mit_spec, "tofino"))
print(f"action table: {mit_spec.n_slots} slots "
      f"({mit_spec.sram_bytes / 1024:.0f} KiB), tofino co-residency "
      f"{'fits' if merged.feasible else 'INFEASIBLE'}: {merged.resources}")

# -- 3. replay an unseen seed of the attack through the mitigated
# pipeline: verdicts until the threshold, MITIGATED drops afterwards
replay = traffic.make_stream("syn_flood", n_packets=N_PACKETS, seed=1)
pipe = StatefulPipeline(pipeline, backend="pallas")
eng = PacketServeEngine(pipe, feature_dim=len(traffic.COLUMNS),
                        max_batch=512)
verdicts = np.concatenate(list(eng.serve_stream(replay.chunks(512))))
print(f"\n[{pipe.backend}] served {len(verdicts)} packets: "
      f"{int((verdicts == MITIGATED).sum())} dropped in-pipeline, "
      f"{int(eng.state.mitigated_flows)} flows marked")

react = traffic.reaction_report(replay, verdicts)
print(f"reaction: detect median {react['reaction_pkts_median']:.0f} pkts, "
      f"+lag {react['mitigation_lag_median']:.0f} to first drop, "
      f"{react['leaked_pkts_total']} leaked after, "
      f"benign collateral {react['benign_mitigated_flow_rate']:.1%}")
assert react["leaked_pkts_total"] == 0

# -- 4. the topology view: the same stream as 4 per-switch slices, and
# the controller-style auto-labels from windowed flow stats
views = traffic.switch_streams(replay, 4)
print(f"\ntopology: {[v.n_packets for v in views]} packets/switch, "
      f"composes back to {traffic.compose_streams(views).n_packets}")
labels = traffic.auto_label(traffic.windowed_flow_stats(replay))
truth = {f: l for f, l in replay.flow_labels.items() if f in labels}
agree = np.mean([labels[f] == l for f, l in truth.items()])
print(f"auto-label vs generation ground truth: {agree:.1%} agreement "
      f"over {len(truth)} flows")
