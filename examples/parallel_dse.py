"""Population-parallel design-space exploration.

Same Figure-3 program as quickstart.py, but letting several candidate
algorithms race (the paper's "multiple parallel runs", footnote 1) with the
batched engine: each round every racer proposes a batch of configurations
(q-EI fantasies), DNN candidates train as ONE vmapped+jitted program per
topology bucket, numpy algorithms fan out over a worker pool, and the
content-addressed candidate cache makes the second generate() call below
nearly free — it retrains nothing.

  PYTHONPATH=src python examples/parallel_dse.py
"""

import time

import homunculus
from homunculus.alchemy import DataLoader, Model, Platforms
from repro.core.traincache import GLOBAL_CACHE
from repro.data import netdata


@DataLoader
def ad_loader():
    return netdata.make_ad_dataset(features=7, n_train=4096, n_test=2048)


model_spec = Model({
    "optimization_metric": ["f1"],
    "algorithm": ["dnn", "svm", "kmeans"],   # race three candidate families
    "name": "anomaly_detection",
    "data_loader": ad_loader,
})

platform = Platforms.Taurus()
platform.constrain(
    performance={"throughput": 1, "latency": 500},  # GPkt/s, ns
    resources={"rows": 16, "cols": 16},
)
platform.schedule(model_spec)

t0 = time.perf_counter()
result = homunculus.generate(platform, budget=24, n_init=6, seed=0,
                             eval_mode="batched", batch_k=8)
first = time.perf_counter() - t0

r = result["anomaly_detection"]
print("\nbest model:", r.summary())
print(f"first generate(): {first:.1f}s   cache: {GLOBAL_CACHE.stats()}")

# re-run: every (algorithm, config, seed, dataset) quadruple is already in
# the content-addressed cache, so the whole search replays without training
t0 = time.perf_counter()
again = homunculus.generate(platform, budget=24, n_init=6, seed=0,
                            eval_mode="batched", batch_k=8)
second = time.perf_counter() - t0
same = again["anomaly_detection"].trained.config == r.trained.config
print(f"re-run generate(): {second:.1f}s ({first / max(second, 1e-9):.1f}x "
      f"faster, same best config: {same})   cache: {GLOBAL_CACHE.stats()}")
