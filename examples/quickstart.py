"""Quickstart: the paper's Figure-3 program, verbatim shape.

An anomaly-detection pipeline declared in ~30 lines of Alchemy: dataset +
objectives + platform constraints in, deployed data-plane pipeline out.

  PYTHONPATH=src python examples/quickstart.py
"""

import homunculus
from homunculus.alchemy import DataLoader, Model, Platforms

from repro.data import netdata


@DataLoader  # training data loader definition
def wrapper_func():
    d = netdata.make_ad_dataset(features=7, n_train=4096, n_test=2048)
    return {
        "data": {"train": d.train_x, "test": d.test_x},
        "labels": {"train": d.train_y, "test": d.test_y},
        "feature_names": d.feature_names,
        "name": "anomaly_detection",
    }


# Specify the model of choice
model_spec = Model({
    "optimization_metric": ["f1"],
    "algorithm": ["dnn"],
    "name": "anomaly_detection",
    "data_loader": wrapper_func,
})

# Load platform
platform = Platforms.Taurus()
platform.constrain(
    performance={
        "throughput": 1,   # GPkt/s
        "latency": 500,    # ns
    },
    resources={"rows": 16, "cols": 16},
)

# Schedule model and generate code
platform.schedule(model_spec)
result = homunculus.generate(platform, budget=14, n_init=6, seed=0)

# ---- inspect what came out
r = result["anomaly_detection"]
print("\nbest model:", r.summary())
data = wrapper_func()
mismatch = r.pipeline.verify(data.test_x)
print(f"pipeline verification vs trained model: {mismatch:.1%} mismatch")
print(f"\ngenerated Spatial (Taurus backend), first 30 lines:\n")
print("\n".join(r.pipeline.source.splitlines()[:30]))
