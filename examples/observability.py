"""The unified telemetry plane, live: one replay, every surface.

A mitigated ``coordinated_ddos`` detector (four staggered attack source
groups, in-pipeline ``Mitigate`` drop table) serves a fresh replay while
an operator watches (docs/pipeline_ir.md#telemetry-contract):

  * a ``DriftDetector`` armed with a BENIGN-traffic snapshot fires as
    the flood onsets shift the packet mix, a background thread retrains
    on the buffered windows, and the new model installs via atomic
    ``engine.swap`` — every step journaled (drift -> retrain_start ->
    retrain_done -> hot_swap) with monotonic timestamps;
  * the action table engages mid-replay (``mitigation_engage`` events,
    ``serve_mitigated_packets_total`` counting dropped packets);
  * a live dashboard renders the metrics registry every few windows:
    throughput, latency percentiles, flow-table occupancy/evictions,
    drain-vs-lockstep schedule shape, mitigation residency;
  * at the end the plane exports everything an operator would mount:
    Prometheus text, the Chrome trace (load in chrome://tracing or
    Perfetto), and the JSON-lines event journal.

  PYTHONPATH=src python examples/observability.py
"""

import json
import os
import tempfile

import numpy as np

from repro.core import codegen, mlalgos, stageir
from repro.data import traffic
from repro.flowstate import (
    MITIGATED,
    DriftDetector,
    DriftSnapshot,
    MitigationSpec,
    StatefulPipeline,
)
from repro.serve import HotSwapController, PacketServeEngine

CHUNK = 512
N_PACKETS = 12_000
N_SLOTS = 2048
MIT_SLOTS = 4096
THRESHOLD = 8
SCENARIO = "coordinated_ddos"

OUT_DIR = tempfile.mkdtemp(prefix="observability-")
JOURNAL = os.path.join(OUT_DIR, "journal.jsonl")
TRACE = os.path.join(OUT_DIR, "trace.json")
PROM = os.path.join(OUT_DIR, "metrics.prom")

stages, names = traffic.flow_feature_stages(n_slots=N_SLOTS)


def train_pipeline(stream, tag: str) -> StatefulPipeline:
    """Detector + drop-mode action table on the stream's ground truth."""
    ds, mu, sd = traffic.stream_feature_dataset(stream, stages, names,
                                                sample_every=4)
    dnn = mlalgos.train_dnn(ds, hidden=[16, 8], epochs=3, seed=0)
    suffix = traffic.fold_input_standardization(
        codegen.taurus_stages(dnn), mu, sd)
    mit = stageir.Mitigate(MitigationSpec(
        n_slots=MIT_SLOTS, mode="drop", threshold=THRESHOLD))
    print(f"  [{tag}] detector trained "
          f"(test F1 {mlalgos.f1_score(ds.test_y, dnn.predict(ds.test_x)):.3f})")
    return StatefulPipeline(list(stages) + suffix + [mit],
                            backend="pallas")


def windows_to_stream(windows, flow_labels) -> traffic.PacketStream:
    pkts = np.concatenate(windows, 0)
    fids = pkts[:, traffic.COL_FLOW].astype(np.int32)
    labels = np.array([flow_labels.get(int(f), 0) for f in fids], np.int32)
    return traffic.PacketStream(f"{SCENARIO}-retrain", pkts, labels,
                                fids, dict(flow_labels))


def _one(snap, name, default=0):
    m = snap.get(name)
    return m["values"][0]["value"] if m and m["values"] else default


def dashboard(engine, tel, served: int, total: int) -> None:
    """One operator-dashboard frame from the live registry + journal."""
    snap = tel.snapshot()
    s = engine.stats()
    drain = _one(snap, "flow_drain_batches_total")
    lockstep = _one(snap, "flow_lockstep_batches_total")
    line = (f"  [{served:6d}/{total}] "
            f"{s['pkt_per_s']:9,.0f} pkt/s  p95 {s['lat_p95_ms']:5.2f} ms"
            f" | table {_one(snap, 'flow_occupancy_frac'):5.1%} full, "
            f"{_one(snap, 'flow_evictions_total'):4.0f} evict"
            f" | sched {lockstep:.0f}L/{drain:.0f}D"
            f" | marked {_one(snap, 'flow_mit_marked'):4.0f} flows, "
            f"dropped {_one(snap, 'serve_mitigated_packets_total'):5.0f}"
            f" | swaps {_one(snap, 'serve_swaps_total'):.0f}")
    events = tel.journal.events()
    if events:
        last = events[-1]
        extra = {k: v for k, v in last.items()
                 if k not in ("seq", "t_s", "wall", "kind")}
        line += f"\n           last event: {last['kind']} {extra}"
    print(line)


# -- 1. train on one seed, arm drift detection against BENIGN traffic
print(f"== train mitigated {SCENARIO} detector ==")
train_stream = traffic.make_stream(SCENARIO, n_packets=N_PACKETS, seed=0)
pipe = train_pipeline(train_stream, "initial")

benign = traffic.make_stream("benign", n_packets=N_PACKETS, seed=0)
snapshot = DriftSnapshot.from_packets(
    benign.packets, cols=(traffic.COL_LEN,), window=CHUNK)
detector = DriftDetector(snapshot, alpha=0.3, threshold=1.2, patience=2)

# -- 2. serve a FRESH replay with the full plane on (the default)
replay = traffic.make_stream(SCENARIO, n_packets=N_PACKETS, seed=1)
engine = PacketServeEngine(pipe, feature_dim=len(traffic.COLUMNS),
                           max_batch=CHUNK, depth=2,
                           telemetry=None)   # default: private full plane
tel = engine.telemetry()                     # in-memory; dumped at the end


def retrain(windows):
    print(f"           drift fired (score {detector.score:.2f}) -> "
          f"background retrain on {len(windows)} buffered windows")
    return train_pipeline(
        windows_to_stream(windows, replay.flow_labels), "retrain")


ctrl = HotSwapController(engine, detector, retrain, buffer_windows=12)

print(f"\n== live replay ({N_PACKETS} packets, dashboard every 4 windows,"
      " L=lockstep D=drain batches) ==")
verdicts, served = [], 0
for i, chunk in enumerate(replay.chunks(CHUNK)):
    ctrl.observe(chunk)
    engine.submit(chunk)
    verdicts.append(engine.flush())
    served += len(chunk)
    if i % 4 == 3:
        dashboard(engine, tel, served, N_PACKETS)
verdicts = np.concatenate(verdicts)

assert ctrl.wait(600), "background retrain did not finish"
assert not ctrl.errors, ctrl.errors
engine.flush()                               # install boundary for the swap
dashboard(engine, tel, served, N_PACKETS)

# -- 3. the operator's story, straight from the journal
print("\n== operator event journal (full trail) ==")
events = tel.journal.events()
for e in events:
    extra = {k: v for k, v in e.items()
             if k not in ("seq", "t_s", "wall", "kind")}
    print(f"  #{e['seq']:<3d} t={e['t_s']:8.3f}s  {e['kind']:<18s} {extra}")

kinds = tel.journal.kinds()
assert {"drift", "retrain_start", "retrain_done", "hot_swap",
        "mitigation_engage"} <= kinds, kinds
ts = [e["t_s"] for e in events]
assert ts == sorted(ts), "journal timestamps must be monotonic"
assert len(verdicts) == replay.n_packets, "packets dropped by observation?"

snap = tel.snapshot()
assert _one(snap, "serve_packets_total") == replay.n_packets
dropped = int((verdicts == MITIGATED).sum())
assert _one(snap, "serve_mitigated_packets_total") == dropped

# -- 4. export every surface
tel.journal.dump(JOURNAL)
with open(TRACE, "w") as f:
    json.dump(tel.chrome_trace(), f)
prom = tel.prometheus()
with open(PROM, "w") as f:
    f.write(prom)

print("\n== prometheus exposition (excerpt) ==")
for line in prom.splitlines():
    if line.startswith(("serve_packets_total", "serve_mitigated",
                        "flow_occupancy", "flow_mit_marked",
                        "serve_batch_latency_ms_bucket{le=\"5\"",
                        "serve_swaps_total")):
        print(f"  {line}")

# detection -> mitigation lag, from the replay's ground truth
react = traffic.reaction_report(replay, verdicts)
print(f"\n== reaction (ground truth vs enforced) ==")
print(f"  detection rate {react['detection_rate']:.3f}  "
      f"reaction median {react['reaction_pkts_median']:.0f} pkts  "
      f"mitigation lag median {react['mitigation_lag_median']:.0f} pkts  "
      f"leaked after first drop {react['leaked_pkts_total']}")

n_spans = len(tel.tracer.spans())
print(f"\nexports -> {OUT_DIR}")
print(f"  journal.jsonl  {len(events)} events "
      f"(drift -> retrain -> hot_swap -> mitigation)")
print(f"  trace.json     {n_spans} spans — load in chrome://tracing")
print(f"  metrics.prom   {len(prom.splitlines())} lines")
print(f"\n{dropped} attack packets dropped in-pipeline, "
      f"{int(_one(snap, 'flow_mit_marked'))} flows marked, one hot swap "
      "mid-mitigation — and the whole story is in the journal.")
