"""Zero-downtime hot swap: drift -> background retrain -> atomic install.

The compiler stages (DSE -> training -> codegen) train offline, but live
traffic drifts.  This example closes the redeployment loop
(docs/pipeline_ir.md#hot-swap-contract) on the ``concept_drift``
scenario, whose attack signature SHIFTS mid-stream — phase A is a
tiny-packet volumetric flood, phase B a stealth MTU flood shaped like
benign bulk traffic:

  1. train the initial model on phase-A traffic through the batched DSE
     racer (``core.dse.retrain_model``);
  2. serve a fresh stream live; a ``DriftDetector`` watches the packet
     windows against a frozen phase-A snapshot, fires when the mix
     shifts, and a ``HotSwapController`` retrains on the drifted windows
     in a BACKGROUND thread (``core.traincache.GLOBAL_CACHE``
     warm-starts repeat episodes) while the engine keeps serving;
  3. the retrained pipeline installs via ``engine.swap`` at a
     dispatch-ring boundary: no batch dropped (verdict count == packet
     count), register state carried bit-identically (same
     ``FlowStateSpec``), and F1 on drifted traffic recovers —
     demonstrated on BOTH ``PacketServeEngine`` and
     ``ShardedPacketServeEngine``.

  PYTHONPATH=src python examples/hot_swap.py
"""

import numpy as np

from repro.core import codegen, dse, mlalgos
from repro.core.alchemy import Platforms
from repro.core.traincache import GLOBAL_CACHE
from repro.data import traffic
from repro.flowstate import DriftDetector, DriftSnapshot, StatefulPipeline
from repro.serve import (
    HotSwapController,
    PacketServeEngine,
    ShardedPacketServeEngine,
)

CHUNK = 512
N_PACKETS = 24_000
N_SLOTS = 2048
SPAN_S = 120.0
SEARCH = dict(algorithms=["dnn"], budget=6, n_init=3, seed=0)

platform = Platforms.Taurus()
platform.constrain(resources={"rows": 16, "cols": 16})

stages, names = traffic.flow_feature_stages(n_slots=N_SLOTS)


def drift_index(stream) -> int:
    """First packet index of phase B (the shifted attack signature)."""
    return int(np.searchsorted(stream.times, SPAN_S * traffic.DRIFT_FRAC))


def search_pipeline(stream, tag: str) -> StatefulPipeline:
    """Features -> batched DSE racer -> stateful serving pipeline, with
    the training-time standardization folded into the first dense layer
    (the served pipeline consumes raw register rows)."""
    ds, mu, sd = traffic.stream_feature_dataset(stream, stages, names,
                                                sample_every=2)
    res = dse.retrain_model(platform, ds, name=tag, **SEARCH)
    suffix = traffic.fold_input_standardization(
        codegen.taurus_stages(res.trained), mu, sd
    )
    print(f"  [{tag}] DSE winner {res.algorithm} "
          f"F1 {res.value:.3f} in {res.wall_s:.1f}s "
          f"(cache: {GLOBAL_CACHE.stats()})")
    return StatefulPipeline(list(stages) + suffix)


def windows_to_stream(windows, flow_labels) -> traffic.PacketStream:
    """The drifted-window retrain corpus as a labeled stream.  Labeling
    policy: scenario ground truth (production systems would use slow-path
    annotation or delayed feedback)."""
    pkts = np.concatenate(windows, 0)
    fids = pkts[:, traffic.COL_FLOW].astype(np.int32)
    labels = np.array([flow_labels.get(int(f), 0) for f in fids], np.int32)
    return traffic.PacketStream("concept_drift-retrain", pkts, labels,
                                fids, dict(flow_labels))


# -- 1. initial model: phase A only (the world before the drift)
print("== train initial model on phase-A traffic ==")
train_stream = traffic.make_stream("concept_drift", n_packets=N_PACKETS,
                                   seed=0)
phase_a = train_stream.slice(0, drift_index(train_stream))
initial_pipe = search_pipeline(phase_a, "phase-a")

# the frozen training-time snapshot the drift statistic scores against
snapshot = DriftSnapshot.from_packets(
    phase_a.packets, cols=(traffic.COL_LEN,), window=CHUNK
)

# the serving stream (fresh seed): drifts at DRIFT_FRAC of the span
eval_stream = traffic.make_stream("concept_drift", n_packets=N_PACKETS,
                                  seed=1)
ev_drift = drift_index(eval_stream)
# fresh drifted traffic served AFTER the swap (the recovery segment)
rec_stream = traffic.make_stream("concept_drift", n_packets=N_PACKETS,
                                 seed=2)
rec_stream = rec_stream.slice(drift_index(rec_stream))


def serve_with_hot_swap(engine, label: str) -> dict:
    detector = DriftDetector(snapshot, alpha=0.25, threshold=1.9,
                             patience=3)

    def retrain(windows):
        print(f"  [{label}] drift fired after {detector.windows} windows "
              f"(score {detector.score:.2f}) -> background retrain on "
              f"{len(windows)} buffered windows")
        return search_pipeline(
            windows_to_stream(windows, eval_stream.flow_labels), "retrain"
        )

    ctrl = HotSwapController(engine, detector, retrain, buffer_windows=24)

    # serve the whole drifting stream; the controller watches every
    # window and launches the retrain mid-stream, the engine keeps
    # serving the old model until the swap installs at a ring boundary
    verdicts = []
    for chunk in eval_stream.chunks(CHUNK):
        ctrl.observe(chunk)
        engine.submit(chunk)
        verdicts.append(engine.flush())
    verdicts = np.concatenate(verdicts)

    assert ctrl.episodes == 1, f"drift never fired ({detector.report()})"
    assert ctrl.wait(600), "retrain did not finish"
    assert not ctrl.errors, ctrl.errors

    # force the install boundary, asserting bit-identical state carry:
    # the swap shares the FlowStateSpec, so the live table must survive
    # the install untouched, bit for bit
    pre_keys = np.array(engine.state.keys)
    pre_regs = np.array(engine.state.regs)
    swaps_before = engine.stats_.swaps
    engine.flush()
    assert engine.stats_.swaps == swaps_before + 1, "swap did not install"
    np.testing.assert_array_equal(pre_keys, np.asarray(engine.state.keys))
    np.testing.assert_array_equal(pre_regs, np.asarray(engine.state.regs))

    # recovery segment: fresh drifted traffic on the NEW model
    rec_verdicts = []
    for chunk in rec_stream.chunks(CHUNK):
        engine.submit(chunk)
        rec_verdicts.append(engine.flush())
    rec_verdicts = np.concatenate(rec_verdicts)

    # zero-downtime accounting: nothing dropped on either side of the swap
    assert len(verdicts) == eval_stream.n_packets
    assert len(rec_verdicts) == rec_stream.n_packets

    stats = engine.stats()
    off = min(stats["swap_pkt_offsets"][0], eval_stream.n_packets)
    f1 = mlalgos.f1_score
    report = {
        "f1_pre_drift": f1(eval_stream.labels[:ev_drift],
                           verdicts[:ev_drift]),
        "f1_post_drift": f1(eval_stream.labels[ev_drift:off],
                            verdicts[ev_drift:off]),
        "f1_post_swap": f1(rec_stream.labels, rec_verdicts),
        "swap_lat_ms": stats["swap_lat_ms"][0],
        "swaps": stats["swaps"],
        "backend_batches": engine.stats_.backend_batches,
    }
    print(f"  [{label}] F1 pre-drift {report['f1_pre_drift']:.3f} -> "
          f"drifted {report['f1_post_drift']:.3f} -> post-swap "
          f"{report['f1_post_swap']:.3f}; swap parked->installed in "
          f"{report['swap_lat_ms']:.1f} ms")
    assert report["f1_pre_drift"] > 0.85, report
    assert report["f1_post_drift"] < 0.5, report
    assert report["f1_post_swap"] > 0.85, report
    return report


print("\n== live serve + hot swap: PacketServeEngine (depth=2) ==")
base_report = serve_with_hot_swap(
    PacketServeEngine(initial_pipe, feature_dim=len(traffic.COLUMNS),
                      max_batch=CHUNK, depth=2),
    "base",
)

print("\n== live serve + hot swap: ShardedPacketServeEngine ==")
# min_shards=1: the full shard_map serving step, whatever the host has;
# the SECOND retrain episode replays the first's training jobs out of
# GLOBAL_CACHE (content-addressed), so the background search is warm
hits_before = GLOBAL_CACHE.stats()["hits"]
sharded_engine = ShardedPacketServeEngine(
    initial_pipe, feature_dim=len(traffic.COLUMNS), max_batch=CHUNK,
    depth=2, min_shards=1,
)
assert sharded_engine.sharded, "shard_map path must engage (min_shards=1)"
sharded_report = serve_with_hot_swap(sharded_engine, "sharded")
hits_gained = GLOBAL_CACHE.stats()["hits"] - hits_before
print(f"  warm retrain: +{hits_gained} trained-candidate cache hits")
assert hits_gained > 0, "second retrain episode should warm-start"

print("\n== summary ==")
for label, rep in (("base", base_report), ("sharded", sharded_report)):
    print(f"  {label:8s} F1 {rep['f1_pre_drift']:.3f} -> "
          f"{rep['f1_post_drift']:.3f} -> {rep['f1_post_swap']:.3f}   "
          f"swap {rep['swap_lat_ms']:.1f} ms   "
          f"batches {rep['backend_batches']}")
print("\nthe model was replaced mid-stream with zero dropped batches and "
      "bit-identical register carry-over — the ROADMAP's online-learning "
      "loop, closed.")
