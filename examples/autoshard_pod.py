"""Beyond-paper: Homunculus's BO searching SHARDING layouts for a pod.

The same constrained-BO core that tunes DNN neurons for a switch here tunes
(dp x tp, microbatches, remat, seq-sharding) for an assigned LM architecture
on a 256-chip pod, with XLA as the compile-in-the-loop feasibility oracle
(fits-in-HBM) and the roofline bound as the objective.

NOTE: each evaluation AOT-compiles the full model — minutes per run.

  PYTHONPATH=src python examples/autoshard_pod.py --arch qwen3-1.7b \
      --shape decode_32k --budget 6
"""

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--budget", type=int, default=6)
    ap.add_argument("--chips", type=int, default=256)
    args = ap.parse_args()

    # the forced-host-device trick requires a fresh process-level setting,
    # exactly like launch/dryrun.py
    import os

    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=512"
    )
    from repro.core.autoshard import autoshard

    print(f"autoshard: {args.arch} x {args.shape} on {args.chips} chips, "
          f"budget {args.budget}")

    def cb(res):
        status = "ok " if res.feasible else "INFEASIBLE"
        print(f"  [{status}] {res.config}  bound={res.t_bound:.4f}s "
              f"(c/m/x {res.t_compute:.3f}/{res.t_memory:.3f}/"
              f"{res.t_collective:.3f})  peak={res.peak_bytes / 2**30:.1f}GiB "
              f"compile={res.wall_s:.0f}s {res.error[:60]}")

    best, evaluated = autoshard(
        args.arch, args.shape, budget=args.budget,
        total_chips=args.chips, callback=cb,
    )
    if best is None:
        print("no feasible layout found within budget")
        return
    print(f"\nbest layout: {best.config}")
    print(f"  roofline bound {best.t_bound:.4f}s/step, dominant "
          f"{best.dominant}, peak {best.peak_bytes / 2**30:.1f} GiB/chip")


if __name__ == "__main__":
    main()
